//! The 1-doubling *exclusive* scan (Section 2).
//!
//! First a shift round moves `V_{r-1}` into `W_r`; from then on the pure
//! exclusive invariant `W_r = ⊕_{i=max(0, r-s_k)}^{r-1} V_i` holds with
//! skips `s_k = 2^{k-1}`, and each subsequent round folds in `W_{r-s_k}`
//! directly — one ⊕ per round, no send-side preparation (the partial sent
//! *is* the partial kept). Equivalent to shifting the input and running the
//! doubling scan on `p−1` ranks: `1 + ⌈log₂(p−1)⌉` rounds,
//! `⌈log₂(p−1)⌉` ⊕ applications.

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::ceil_log2;

/// 1-doubling exclusive scan (shift + doubling on p−1 ranks).
pub struct ExscanOneDoubling;

impl<T: Elem> ScanAlgorithm<T> for ExscanOneDoubling {
    fn name(&self) -> &'static str {
        "1-doubling"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p) = (ctx.rank(), ctx.size());
        if p <= 1 {
            return Ok(());
        }
        // Resolve ⊕ to its slice kernel once for the whole collective
        // (the per-application dispatch is then a direct call — mpi::op).
        let op = &ctx.kernel(op);
        // Round 0 (s_0 = 1): shift inputs right. Rank 0 only sends and is
        // then done (it neither holds nor contributes any further partial).
        let (to, from) = (r + 1, r.checked_sub(1));
        match (to < p, from) {
            (true, Some(f)) => ctx.sendrecv(0, to, input, f, output)?,
            (true, None) => ctx.send(0, to, input)?,
            (false, Some(f)) => ctx.recv(0, f, output)?,
            (false, None) => unreachable!("p > 1"),
        }
        if r == 0 {
            return Ok(());
        }

        // Rounds k >= 1 with s_k = 2^{k-1}: the doubling scan over the
        // shifted inputs on ranks 1..p, on the fused primitives (the value
        // sent is the value kept; the received partial folds straight from
        // the pooled buffer: W = W_{r-s} ⊕ W). Receives come only from
        // ranks >= 1 (rank 0 left the algorithm), sends go to r + s_k < p.
        let mut s = 1usize;
        let mut k = 1u32;
        while s < p - 1 {
            let to = r + s;
            let from = if r > s { Some(r - s) } else { None }; // from >= 1
            match (to < p, from) {
                (true, Some(f)) => ctx.sendrecv_reduce(k, to, f, op, output)?,
                (true, None) => ctx.send(k, to, output)?,
                (false, Some(f)) => ctx.recv_reduce(k, f, op, output)?,
                (false, None) => {}
            }
            s *= 2;
            k += 1;
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        match p {
            0 | 1 => 0,
            2 => 1,
            _ => 1 + ceil_log2(p - 1),
        }
    }

    /// One ⊕ per doubling round on the last rank: `⌈log₂(p−1)⌉`.
    fn predicted_ops(&self, p: usize) -> u32 {
        match p {
            0 | 1 | 2 => 0,
            _ => ceil_log2(p - 1),
        }
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        let mut out = vec![1]; // the shift round
        let mut s = 1;
        while s < p.saturating_sub(1) {
            out.push(s);
            s *= 2;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle_many_p() {
        for p in [2usize, 3, 4, 5, 6, 7, 8, 9, 16, 17, 33, 36] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| vec![(r as i64).wrapping_mul(0x9E37) ^ 5, r as i64 - 3]).collect();
            let res = run_scan(&cfg, &ExscanOneDoubling, &ops::bxor(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        }
    }

    #[test]
    fn rounds_and_ops_match_paper_counts() {
        for p in [2usize, 3, 4, 5, 8, 9, 17, 36, 37] {
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
            let res = run_scan(&cfg, &ExscanOneDoubling, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            let algo: &dyn ScanAlgorithm<i64> = &ExscanOneDoubling;
            assert_eq!(trace.total_rounds(), algo.predicted_rounds(p), "rounds p={p}");
            assert_eq!(trace.last_rank_ops(), algo.predicted_ops(p), "ops p={p}");
            // 1-doubling never needs a send-side ⊕: max == last-rank count.
            assert_eq!(trace.max_ops(), algo.predicted_ops(p), "max ops p={p}");
            assert!(crate::trace::check_all(&trace).is_empty(), "invariants p={p}");
        }
    }

    #[test]
    fn paper_round_counts_36() {
        let algo: &dyn ScanAlgorithm<i64> = &ExscanOneDoubling;
        assert_eq!(algo.predicted_rounds(36), 7); // 1 + ceil(log2 35) = 7
        assert_eq!(algo.predicted_rounds(1152), 12);
        assert_eq!(algo.predicted_ops(36), 6);
    }
}
