//! Segmented scans by operator lifting (Blelloch's classic construction,
//! reference [1] of the paper): a scan over `(flag, value)` pairs under a
//! lifted operator computes independent prefix sums for every
//! flag-delimited segment — with *any* of the scan algorithms in this
//! library, unchanged, because the lifted operator is associative.
//!
//! `(f₁,v₁) ⊕̂ (f₂,v₂) = (f₁ ∨ f₂,  if f₂ { v₂ } else { v₁ ⊕ v₂ })`
//!
//! Segments here span *ranks* (each rank contributes one element per
//! vector lane): the common use is per-group offsets where groups are
//! contiguous rank ranges (e.g. per-node numbering).

use crate::mpi::{CombineOp, Dtype, Elem, OpRef};

/// A value tagged with a segment-start flag.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Seg<T> {
    /// True iff this element starts a new segment.
    pub flag: bool,
    pub val: T,
}

impl<T> Seg<T> {
    pub fn new(flag: bool, val: T) -> Self {
        Seg { flag, val }
    }

    pub fn start(val: T) -> Self {
        Seg { flag: true, val }
    }

    pub fn cont(val: T) -> Self {
        Seg { flag: false, val }
    }
}

impl<T: Elem> Elem for Seg<T> {
    const DTYPE: Dtype = Dtype::Composite;

    fn filler() -> Self {
        Seg { flag: false, val: T::filler() }
    }

    // Wire form: one flag byte (0/1) + the inner element. The in-memory
    // struct may pad the bool; the explicit encoding never ships padding,
    // so segmented scans run over the shm/socket backends too.
    fn wire_bytes() -> usize {
        1 + T::wire_bytes()
    }

    fn write_wire(&self, out: &mut Vec<u8>) {
        out.push(self.flag as u8);
        self.val.write_wire(out);
    }

    fn read_wire(bytes: &[u8]) -> Self {
        Seg { flag: bytes[0] != 0, val: T::read_wire(&bytes[1..]) }
    }
}

/// The lifted operator over a scalar combine function.
pub struct LiftedOp<T, F> {
    name: String,
    f: F,
    _t: std::marker::PhantomData<T>,
}

impl<T: Elem, F: Fn(T, T) -> T + Send + Sync> CombineOp<Seg<T>> for LiftedOp<T, F> {
    fn name(&self) -> &str {
        &self.name
    }

    fn combine(&self, input: &[Seg<T>], inout: &mut [Seg<T>]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            if o.flag {
                // `o` starts a segment: the earlier value cannot cross it.
            } else {
                o.val = (self.f)(i.val, o.val);
                o.flag = i.flag;
            }
        }
    }

    /// The lifted operator is never commutative (the flag rule is
    /// direction-sensitive), even if the base operator is.
    fn commutative(&self) -> bool {
        false
    }
}

/// Lift a scalar combine into a segmented operator.
pub fn lift<T: Elem, F: Fn(T, T) -> T + Send + Sync + 'static>(
    name: &str,
    f: F,
) -> OpRef<Seg<T>> {
    OpRef::new(std::sync::Arc::new(LiftedOp {
        name: format!("seg_{name}"),
        f,
        _t: std::marker::PhantomData,
    }))
}

/// Segmented i64 sum — per-segment offsets.
pub fn seg_sum_i64() -> OpRef<Seg<i64>> {
    lift("sum_i64", |a: i64, b: i64| a.wrapping_add(b))
}

/// Segmented i64 max.
pub fn seg_max_i64() -> OpRef<Seg<i64>> {
    lift("max_i64", |a: i64, b: i64| a.max(b))
}

/// Segmented i64 BXOR (the paper's benchmark operator, lifted — used by
/// the chaos fuzz grid to pin segmented-operator correctness under
/// adversarial delivery).
pub fn seg_bxor_i64() -> OpRef<Seg<i64>> {
    lift("bxor_i64", |a: i64, b: i64| a ^ b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::{Exscan123, ExscanBlelloch, ExscanMpich, ScanAlgorithm, ScanDoubling};
    use crate::mpi::{run_scan, Topology, WorldConfig};

    /// Sequential segmented inclusive scan for the oracle.
    fn seg_scan_ref(xs: &[Seg<i64>]) -> Vec<i64> {
        let mut out = Vec::with_capacity(xs.len());
        let mut acc = 0i64;
        for x in xs {
            acc = if x.flag { x.val } else { acc + x.val };
            out.push(acc);
        }
        out
    }

    #[test]
    fn lifted_operator_is_associative() {
        let op = seg_sum_i64();
        let cases = [
            (Seg::cont(1), Seg::cont(2), Seg::cont(3)),
            (Seg::start(1), Seg::cont(2), Seg::cont(3)),
            (Seg::cont(1), Seg::start(2), Seg::cont(3)),
            (Seg::cont(1), Seg::cont(2), Seg::start(3)),
            (Seg::start(1), Seg::start(2), Seg::start(3)),
        ];
        for (a, b, c) in cases {
            // (a ⊕ b) ⊕ c
            let mut ab = [b];
            op.reduce_local_sharded(0, &[a], &mut ab);
            let mut ab_c = [c];
            op.reduce_local_sharded(0, &ab, &mut ab_c);
            // a ⊕ (b ⊕ c)
            let mut bc = [c];
            op.reduce_local_sharded(0, &[b], &mut bc);
            let mut a_bc = bc;
            op.reduce_local_sharded(0, &[a], &mut a_bc);
            assert_eq!(ab_c, a_bc, "{a:?} {b:?} {c:?}");
        }
    }

    #[test]
    fn segmented_inclusive_scan_over_ranks() {
        let p = 17;
        // Segments start at ranks 0, 5, 11.
        let inputs: Vec<Vec<Seg<i64>>> = (0..p)
            .map(|r| vec![Seg::new(r == 0 || r == 5 || r == 11, r as i64 + 1)])
            .collect();
        let flat: Vec<Seg<i64>> = inputs.iter().map(|v| v[0]).collect();
        let expect = seg_scan_ref(&flat);
        let cfg = WorldConfig::new(Topology::flat(p));
        let res = run_scan(&cfg, &ScanDoubling, &seg_sum_i64(), &inputs).unwrap();
        for r in 0..p {
            assert_eq!(res.outputs[r][0].val, expect[r], "rank {r}");
        }
    }

    #[test]
    fn segmented_exscan_gives_per_segment_offsets() {
        let p = 12;
        let seg_starts = [0usize, 4, 8];
        let counts: Vec<i64> = (0..p).map(|r| (r % 5 + 1) as i64).collect();
        let inputs: Vec<Vec<Seg<i64>>> = (0..p)
            .map(|r| vec![Seg::new(seg_starts.contains(&r), counts[r])])
            .collect();
        for algo in [&Exscan123 as &dyn ScanAlgorithm<Seg<i64>>, &ExscanMpich, &ExscanBlelloch] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let res = run_scan(&cfg, algo, &seg_sum_i64(), &inputs).unwrap();
            // Within each segment, rank r's exclusive offset = sum of
            // counts from its segment start up to r-1 — UNLESS r starts a
            // segment (then the incoming prefix belongs to the previous
            // segment and is ignored by convention).
            for r in 1..p {
                if seg_starts.contains(&r) {
                    continue;
                }
                let seg_start = *seg_starts.iter().filter(|&&s| s <= r).max().unwrap();
                let expect: i64 = counts[seg_start..r].iter().sum();
                assert_eq!(res.outputs[r][0].val, expect, "{} rank {r}", algo.name());
            }
        }
    }
}
