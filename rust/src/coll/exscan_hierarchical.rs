//! Hierarchical (SMP-aware) exclusive scan — an extension ablation: is it
//! worth exploiting the node structure instead of running the flat
//! 123-doubling over all p ranks?
//!
//! Three phases:
//!   1. **Gather**: each node's ranks chain their vectors to the node
//!      leader (k−1 one-ported rounds for k ranks/node).
//!   2. **Leader scan**: leaders compute (a) the node-local *block
//!      exclusive scan* over the k contributions — natively, or in ONE
//!      fused Pallas-kernel launch via PJRT ([`crate::runtime`]) — and
//!      (b) run the 123-doubling exscan over the node *totals* (log of
//!      #nodes rounds, all inter-node).
//!   3. **Scatter**: leaders send each rank `node_prefix ⊕ local_row`.
//!
//! Verdict (see `benches/rounds_ablation.rs` and EXPERIMENTS.md): at the
//! paper's calibrated parameters the flat 123-doubling wins — its
//! intra-node rounds are already cheap — but the hierarchical variant
//! trades 2(k−1) cheap rounds for an inter-node exscan that is 5 rounds
//! shorter at 36×32, so it wins when the inter/intra latency ratio grows
//! beyond ≈20×. The cost model predicts the crossover; the simulation
//! confirms it.

use anyhow::Result;

use super::basic::{gather_chain, scatter_chain};
use super::{Exscan123, ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::bits::rounds_123;

/// Topology-aware two-level exclusive scan.
pub struct ExscanHierarchical {
    /// Ranks per node (block placement, as [`crate::mpi::Topology`]).
    pub ranks_per_node: usize,
}

impl ExscanHierarchical {
    pub fn new(ranks_per_node: usize) -> Self {
        assert!(ranks_per_node >= 1);
        ExscanHierarchical { ranks_per_node }
    }
}

impl<T: Elem> ScanAlgorithm<T> for ExscanHierarchical {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p, m) = (ctx.rank(), ctx.size(), input.len());
        if p <= 1 {
            return Ok(());
        }
        let k = self.ranks_per_node.min(p);
        if k == 1 {
            // Degenerate: flat 123-doubling.
            return ScanAlgorithm::<T>::run(&Exscan123, ctx, input, output, op);
        }
        // Resolve ⊕ to its slice kernel once for the whole collective
        // (the per-application dispatch is then a direct call — mpi::op).
        let op = &ctx.kernel(op);
        let node = r / k;
        let leader = node * k;
        let node_size = k.min(p - leader); // last node may be short
        let group: Vec<usize> = (leader..leader + node_size).collect();

        // Phase 1: gather the node's vectors at the leader (rows).
        let mut rows = if r == leader { vec![T::filler(); node_size * m] } else { vec![] };
        gather_chain(ctx, 0, &group, input, &mut rows)?;
        // Uniform round bases across nodes (a short last node must still
        // tag the inter-node rounds identically to full nodes).
        let after_gather = (k - 1) as u32;

        // Phase 2 (leader): block exscan over rows + node total, then the
        // inter-node 123-doubling exscan over totals. Leaders are ranks
        // {0, k, 2k, …}; the sub-communicator is expressed by translating
        // ranks: leader of node j talks to leaders of j ± skip.
        let mut local_prefix_rows = vec![T::filler(); if r == leader { node_size * m } else { 0 }];
        let mut node_prefix = ctx.scratch_filled(m);
        let mut have_node_prefix = false;
        if r == leader {
            // Exclusive scan across the node's rows, in place: row j is
            // promoted to the inclusive partial row_0 ⊕ … ⊕ row_j and acc
            // trails it (pooled scratch; no per-row temporaries). Row 0's
            // prefix is "empty" (tracked out of band — no identity needed).
            let mut acc = ctx.scratch_from(&rows[..m]);
            for j in 1..node_size {
                local_prefix_rows[j * m..(j + 1) * m].copy_from_slice(&acc);
                let row = &mut rows[j * m..(j + 1) * m];
                ctx.reduce_local(after_gather, op, &acc, row); // row = acc ⊕ row
                acc.copy_from(row);
            }
            let total = acc;

            // Inter-node exclusive scan over totals: the shared
            // translated-123 engine ([`super::exscan_123::exscan_123_group`])
            // over the leader list (leader of node j = j·k), on the fused
            // receive-reduce primitives.
            let nodes = p.div_ceil(k);
            let leaders: Vec<usize> = (0..nodes).map(|j| j * k).collect();
            have_node_prefix = super::exscan_123::exscan_123_group(
                ctx,
                after_gather,
                &leaders,
                op,
                &total,
                &mut node_prefix,
            )?;
        }

        // Phase 3: scatter node_prefix ⊕ local_prefix_row to each rank.
        // (Uniform base: gather rounds + inter-node rounds + 1 slack.)
        let scatter_base = after_gather + rounds_123(p.div_ceil(k)).max(1) + 1;
        debug_assert!(scatter_base >= after_gather);
        let mut out_rows = vec![T::filler(); if r == leader { node_size * m } else { 0 }];
        if r == leader {
            for j in 0..node_size {
                let row = &mut out_rows[j * m..(j + 1) * m];
                if j == 0 {
                    // Row 0's local prefix is empty: prefix is the node's.
                    if have_node_prefix {
                        row.copy_from_slice(&node_prefix);
                    }
                } else {
                    row.copy_from_slice(&local_prefix_rows[j * m..(j + 1) * m]);
                    if have_node_prefix {
                        // node_prefix is earlier than the local rows;
                        // combine in place, no per-row temporary.
                        ctx.reduce_local(scatter_base, op, &node_prefix, row);
                    }
                }
            }
        }
        scatter_chain(ctx, scatter_base, &group, &out_rows, output)?;
        // Rank 0 of the world: output undefined (exclusive scan), but the
        // scatter delivered the leader's row 0 (empty prefix) — leave it.
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        let k = self.ranks_per_node.min(p).max(1);
        if k == 1 {
            return rounds_123(p);
        }
        let nodes = p.div_ceil(k);
        2 * (k as u32 - 1) + rounds_123(nodes)
    }

    fn predicted_ops(&self, p: usize) -> u32 {
        let k = self.ranks_per_node.min(p).max(1) as u32;
        let nodes = p.div_ceil(k as usize);
        // Leader: k-1 block folds + (q-1) inter-node + k-1 scatter combines.
        (k - 1) + rounds_123(nodes).saturating_sub(1) + (k - 1)
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        let k = self.ranks_per_node.min(p).max(1);
        let nodes = p.div_ceil(k);
        let mut skips = vec![1; k - 1]; // gather (intra)
        for (j, s) in super::exscan_123::Exscan123
            .critical_skips_nodes(nodes)
            .into_iter()
            .enumerate()
        {
            let _ = j;
            skips.push(s * k); // leader hops are node-distance × k ranks
        }
        skips.extend(vec![1; k - 1]); // scatter (intra)
        skips
    }
}

impl Exscan123 {
    /// Skip sequence reused by the hierarchical wrapper.
    pub(crate) fn critical_skips_nodes(&self, nodes: usize) -> Vec<usize> {
        <Exscan123 as ScanAlgorithm<i64>>::critical_skips(self, nodes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle_various_shapes() {
        for (nodes, k) in [(2usize, 2usize), (3, 4), (4, 3), (6, 8), (5, 1), (1, 4)] {
            let p = nodes * k;
            let algo = ExscanHierarchical::new(k);
            let cfg = WorldConfig::new(Topology::cluster(nodes, k));
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| vec![(r as i64) * 3 + 1, !(r as i64)]).collect();
            let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        }
    }

    #[test]
    fn short_last_node() {
        // p not divisible by k: last node has fewer ranks.
        let (k, p) = (4usize, 10usize);
        let algo = ExscanHierarchical::new(k);
        let cfg = WorldConfig::new(Topology::flat(p));
        let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![1i64 << r]).collect();
        let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
        assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
    }

    #[test]
    fn noncommutative_hierarchical() {
        use crate::bench::inputs_rec2;
        use crate::coll::validate::oracle_exscan;
        let (nodes, k) = (3usize, 3usize);
        let p = nodes * k;
        let algo = ExscanHierarchical::new(k);
        let cfg = WorldConfig::new(Topology::cluster(nodes, k));
        let inputs = inputs_rec2(p, 2, 31);
        let res = run_scan(&cfg, &algo, &ops::rec2_compose(), &inputs).unwrap();
        let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
        for r in 1..p {
            let e = oracle[r].as_ref().unwrap();
            for (a, b) in res.outputs[r].iter().zip(e) {
                for i in 0..4 {
                    assert!((a.a[i] - b.a[i]).abs() < 1e-3, "r={r}");
                }
            }
        }
    }

    #[test]
    fn one_ported_invariant_holds() {
        let algo = ExscanHierarchical::new(4);
        let cfg = WorldConfig::new(Topology::cluster(4, 4)).with_trace(true);
        let inputs: Vec<Vec<i64>> = (0..16).map(|r| vec![r as i64]).collect();
        let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
        let tr = res.trace.unwrap();
        assert!(crate::trace::check_all(&tr).is_empty());
    }
}
