//! The two-⊕ doubling *exclusive* scan (Section 2).
//!
//! The doubling inclusive scan, extended to maintain the exclusive
//! invariant after the first round:
//! `W_r = ⊕_{i=max(0, r-s_k+1)}^{r-1} V_i` with skips `s_k = 2^k`.
//! Because the value a peer needs is `W_r ⊕ V_r` (the *inclusive* partial)
//! while the value kept is the exclusive partial, every round after the
//! first costs **two** ⊕ applications on ranks that both send and receive:
//! one to prepare the outgoing `W ⊕ V`, one to fold the incoming partial.
//! `⌈log₂p⌉` rounds, `2⌈log₂p⌉ − 1` ⊕ applications in the worst rank.

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::ceil_log2;

/// Two-⊕ doubling exclusive scan.
pub struct ExscanTwoOp;

impl<T: Elem> ScanAlgorithm<T> for ExscanTwoOp {
    fn name(&self) -> &'static str {
        "two-op-doubling"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p, m) = (ctx.rank(), ctx.size(), input.len());
        if p <= 1 {
            return Ok(()); // rank 0 output undefined
        }
        // Resolve ⊕ to its slice kernel once for the whole collective
        // (the per-application dispatch is then a direct call — mpi::op).
        let op = &ctx.kernel(op);
        // Pooled scratch for the outgoing inclusive partial, reused across
        // rounds (zero steady-state allocations).
        let mut w_prime = ctx.scratch_filled(m);

        // Round 0 (s = 1): pure shift — send V to r+1, receive V_{r-1}
        // into W. No ⊕. Establishes W_r = ⊕_{i=r-1}^{r-1} V_i.
        let (to, from) = (r + 1, r.checked_sub(1));
        match (to < p, from) {
            (true, Some(f)) => ctx.sendrecv(0, to, input, f, output)?,
            (true, None) => ctx.send(0, to, input)?,
            (false, Some(f)) => ctx.recv(0, f, output)?,
            (false, None) => unreachable!("p > 1"),
        }

        // Rounds k >= 1 (s = 2^k): send the inclusive partial W ⊕ V,
        // fold the received exclusive-extension partial into W.
        let mut s = 2usize;
        let mut k = 1u32;
        while s < p {
            let to = r + s;
            let from = r.checked_sub(s);
            let sends = to < p;
            let recvs = from.is_some(); // r >= s: fold in the partial from r-s
            if sends {
                // W' = W ⊕ V (W is the earlier operand: it covers indices
                // strictly below those of V_r).
                w_prime.copy_from_slice(input);
                if r >= 1 {
                    ctx.reduce_local(k, op, output, &mut w_prime);
                } // rank 0 has no W: its inclusive partial is V itself.
            }
            match (sends, recvs, from) {
                (true, true, Some(f)) => {
                    // W = T ⊕ W, fused straight from the receive buffer.
                    ctx.sendrecv_reduce_into(k, to, &w_prime, f, op, output)?
                }
                (true, false, _) => ctx.send(k, to, &w_prime)?,
                (false, true, Some(f)) => ctx.recv_reduce(k, f, op, output)?,
                _ => {}
            }
            s *= 2;
            k += 1;
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        if p <= 1 {
            0
        } else {
            ceil_log2(p)
        }
    }

    /// The paper's count: two ⊕ per round except the first, on the
    /// busiest rank: `2⌈log₂p⌉ − 1`.
    fn predicted_ops(&self, p: usize) -> u32 {
        if p <= 1 {
            0
        } else {
            2 * ceil_log2(p) - 1
        }
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        // Last rank receives with every doubling skip.
        let mut out = Vec::new();
        let mut s = 1;
        while s < p {
            out.push(s);
            s *= 2;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle_many_p() {
        for p in [2usize, 3, 4, 5, 6, 7, 8, 9, 16, 17, 33, 36] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| vec![(r as i64) << 3 | 1, !(r as i64)]).collect();
            let res = run_scan(&cfg, &ExscanTwoOp, &ops::bxor(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        }
    }

    #[test]
    fn rounds_and_max_ops_match_paper_counts() {
        for p in [2usize, 3, 4, 5, 8, 9, 17, 36] {
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
            let res = run_scan(&cfg, &ExscanTwoOp, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            let algo: &dyn ScanAlgorithm<i64> = &ExscanTwoOp;
            assert_eq!(trace.total_rounds(), algo.predicted_rounds(p), "rounds p={p}");
            // The paper's 2⌈log₂p⌉−1 is the critical-chain count (send
            // preparation of round k is serialized with round k+1's fold
            // across ranks); the per-rank maximum is bounded by it, and
            // must exceed the inclusive scan's count for p ≥ 8 — the
            // two-⊕ penalty the paper's analysis is about.
            assert!(trace.max_ops() <= algo.predicted_ops(p), "max ops p={p}");
            if p >= 8 {
                assert!(trace.max_ops() > crate::util::ceil_log2(p) - 1, "penalty p={p}");
            }
            assert!(crate::trace::check_all(&trace).is_empty(), "invariants p={p}");
        }
    }

    #[test]
    fn noncommutative() {
        use crate::coll::validate::oracle_exscan;
        use crate::mpi::Rec2;
        let p = 11;
        let cfg = WorldConfig::new(Topology::flat(p));
        let inputs: Vec<Vec<Rec2>> = (0..p)
            .map(|r| vec![Rec2::new([1.0, 0.1 * r as f32, 0.0, 1.0], [1.0, r as f32])])
            .collect();
        let res = run_scan(&cfg, &ExscanTwoOp, &ops::rec2_compose(), &inputs).unwrap();
        let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
        for r in 1..p {
            let e = oracle[r].as_ref().unwrap();
            for i in 0..2 {
                assert!((res.outputs[r][0].b[i] - e[0].b[i]).abs() < 1e-3, "r={r}");
            }
        }
    }
}
