//! The straight-doubling *inclusive* scan (Hillis-Steele / Kogge-Stone /
//! Kruskal-Rudolph-Snir), Section 2 of the paper.
//!
//! Invariant before round k (skips `s_k = 2^k`):
//! `W_r = ⊕_{i=max(0, r-s_k+1)}^{r} V_i`.
//! Each round, rank r sends its partial W to `r+s_k` and receives
//! `W_{r-s_k}` which is folded in from the left. `⌈log₂p⌉` rounds,
//! `⌈log₂p⌉` ⊕ applications on the last rank; round-optimal for the
//! inclusive problem in the one-ported model.

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::ceil_log2;

/// Straight-doubling inclusive scan (`MPI_Scan` counterpart).
pub struct ScanDoubling;

impl<T: Elem> ScanAlgorithm<T> for ScanDoubling {
    fn name(&self) -> &'static str {
        "doubling-scan"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Inclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p) = (ctx.rank(), ctx.size());
        // Resolve ⊕ to its slice kernel once for the whole collective
        // (the per-application dispatch is then a direct call — mpi::op).
        let op = &ctx.kernel(op);
        output.copy_from_slice(input); // W_r := V_r establishes the invariant
        let mut s = 1usize; // s_k = 2^k
        let mut k = 0u32;
        while s < p {
            let to = r + s;
            let from = r.checked_sub(s);
            match (to < p, from) {
                (true, Some(f)) => {
                    // Fused simultaneous send-receive-reduce: the transport
                    // copies the send buffer on post, then W = T ⊕ W folds
                    // straight from the pooled receive buffer.
                    ctx.sendrecv_reduce(k, to, f, op, output)?
                }
                (true, None) => ctx.send(k, to, output)?,
                (false, Some(f)) => ctx.recv_reduce(k, f, op, output)?,
                (false, None) => {} // p == 1
            }
            s *= 2;
            k += 1;
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        if p <= 1 {
            0
        } else {
            ceil_log2(p)
        }
    }

    fn predicted_ops(&self, p: usize) -> u32 {
        // Last rank folds one received partial per round.
        <Self as ScanAlgorithm<T>>::predicted_rounds(self, p)
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut s = 1;
        while s < p {
            out.push(s);
            s *= 2;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::oracle_scan;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn inclusive_scan_matches_oracle() {
        for p in [1usize, 2, 3, 4, 5, 8, 13, 36] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| vec![(r * r + 1) as i64, r as i64]).collect();
            let res = run_scan(&cfg, &ScanDoubling, &ops::sum_i64(), &inputs).unwrap();
            let oracle = oracle_scan(&inputs, &ops::sum_i64());
            assert_eq!(res.outputs, oracle, "p={p}");
        }
    }

    #[test]
    fn rounds_match_prediction() {
        for p in [2usize, 3, 5, 8, 9, 36] {
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
            let res = run_scan(&cfg, &ScanDoubling, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            let algo: &dyn ScanAlgorithm<i64> = &ScanDoubling;
            assert_eq!(trace.total_rounds(), algo.predicted_rounds(p), "p={p}");
            assert_eq!(trace.last_rank_ops(), algo.predicted_ops(p), "p={p}");
            assert!(crate::trace::check_all(&trace).is_empty());
        }
    }

    #[test]
    fn noncommutative_order_respected() {
        use crate::mpi::Rec2;
        let p = 7;
        let cfg = WorldConfig::new(Topology::flat(p));
        let inputs: Vec<Vec<Rec2>> = (0..p)
            .map(|r| {
                vec![Rec2::new(
                    [1.0 + r as f32, 0.5, -0.25, 1.0 - r as f32 * 0.1],
                    [r as f32, -(r as f32)],
                )]
            })
            .collect();
        let res = run_scan(&cfg, &ScanDoubling, &ops::rec2_compose(), &inputs).unwrap();
        let oracle = oracle_scan(&inputs, &ops::rec2_compose());
        for r in 0..p {
            for i in 0..4 {
                assert!((res.outputs[r][0].a[i] - oracle[r][0].a[i]).abs() < 1e-3, "p7 r{r}");
            }
        }
    }
}
