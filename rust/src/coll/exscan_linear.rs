//! Linear-pipeline ("ring walk") exclusive scan: `p−1` rounds, exactly one
//! ⊕ per interior rank. The round count is hopeless for small vectors, but
//! the algorithm moves each byte only once per hop and is the degenerate
//! (B = 1) case of [`super::PipelinedChain`]; kept as the sanity baseline
//! the logarithmic algorithms are measured against.

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};

/// Linear exclusive scan: rank r receives `W_r` from `r−1`, forwards
/// `W_r ⊕ V_r` to `r+1`.
pub struct ExscanLinear;

impl<T: Elem> ScanAlgorithm<T> for ExscanLinear {
    fn name(&self) -> &'static str {
        "linear"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p) = (ctx.rank(), ctx.size());
        if p <= 1 {
            return Ok(());
        }
        // Resolve ⊕ to its slice kernel once for the whole collective
        // (the per-application dispatch is then a direct call — mpi::op).
        let op = &ctx.kernel(op);
        if r == 0 {
            ctx.send(0, 1, input)?;
            return Ok(());
        }
        // Receive the exclusive prefix from the left (round r-1)…
        ctx.recv((r - 1) as u32, r - 1, output)?;
        // …and forward the inclusive extension to the right (round r),
        // prepared in a pooled scratch buffer (no per-hop allocation).
        if r + 1 < p {
            let mut fwd = ctx.scratch_from(input);
            ctx.reduce_local(r as u32, op, output, &mut fwd); // W earlier
            ctx.send(r as u32, r + 1, &fwd)?;
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        p.saturating_sub(1) as u32
    }

    fn predicted_ops(&self, _p: usize) -> u32 {
        1
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        vec![1; p.saturating_sub(1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle() {
        for p in [2usize, 3, 7, 16, 36] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64 + 1, 2]).collect();
            let res = run_scan(&cfg, &ExscanLinear, &ops::sum_i64(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
        }
    }

    #[test]
    fn exactly_p_minus_1_rounds_one_op() {
        let p = 9;
        let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
        let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
        let res = run_scan(&cfg, &ExscanLinear, &ops::bxor(), &inputs).unwrap();
        let trace = res.trace.unwrap();
        assert_eq!(trace.total_rounds(), 8);
        assert_eq!(trace.max_ops(), 1);
        assert!(crate::trace::check_all(&trace).is_empty());
    }
}
