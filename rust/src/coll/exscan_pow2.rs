//! **Pow2-doubling exclusive scan** — the fully-fortified algorithm from
//! Träff's 2026 follow-up *"Two Efficient Message-passing Exclusive Scan
//! Algorithms"*: every round sends the *inclusive* partial `W ⊕ V`, which
//! drives the round count down to the one-ported information lower bound
//! `⌈log₂ p⌉` at the price of roughly one extra ⊕ per rank per round.
//!
//! Invariant before round `k`: rank `r` holds `W` covering its
//! `min(2^k − 1, r)` trailing inputs `V_{r−c} … V_{r−1}`. Round `k`
//! (skip `2^k`): rank `r` sends `W ⊕ V` (covering `min(2^k, r+1)`
//! trailing inputs *ending at* `V_r`) to `r + 2^k` iff that exists, and
//! receives from `r − 2^k` iff `r ≥ 2^k`, folding the incoming partial
//! as the *earlier* operand. The two operands abut exactly, so coverage
//! doubles (+1): after round `k` it is `min(2^{k+1} − 1, r)` and rank
//! `p−1` completes once `2^q − 1 ≥ p − 1`, i.e. after `⌈log₂ p⌉` rounds.
//!
//! Compared to [`Exscan123`](super::Exscan123) (one fortified round,
//! `⌈log₂(p−1) + log₂(4/3)⌉` rounds, ~1 ⊕/rank/round) this is the other
//! end of the fortification ladder: every round fortified, fewest
//! possible rounds, up to 2 ⊕ per rank per round. [`Exscan1247`]
//! (two fortified rounds) sits between them.
//!
//! Closed forms (checked against traces): rounds `K = ⌈log₂ p⌉`;
//! completion-critical rank `p−1` applies `K − 1` ⊕ (its round-0 receive
//! is a plain copy); no rank applies more than `2(K−1)`.
//!
//! [`Exscan1247`]: super::Exscan1247

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::bits::rounds_pow2;

/// Fully-fortified pow2-doubling exclusive scan (2026 follow-up paper).
pub struct ExscanPow2;

impl<T: Elem> ScanAlgorithm<T> for ExscanPow2 {
    fn name(&self) -> &'static str {
        "pow2-doubling"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p) = (ctx.rank(), ctx.size());
        if p <= 1 {
            return Ok(());
        }
        let op = &ctx.kernel(op);
        // ── Round 0, skip 1: plain shift. The outgoing inclusive partial
        // is just V (W is still empty everywhere), and the incoming V_{r-1}
        // is a copy, not a fold — this is where the critical rank saves
        // its ⊕ relative to the naive two-⊕ doubling. ──
        {
            let (t, f) = (r + 1, r.checked_sub(1));
            match (t < p, f) {
                (true, Some(f)) => ctx.sendrecv(0, t, input, f, output)?,
                (true, None) => ctx.send(0, t, input)?, // rank 0
                (false, Some(f)) => ctx.recv(0, f, output)?, // rank p-1
                (false, None) => unreachable!("p > 1"),
            }
        }

        // ── Rounds k >= 1, skip 2^k: send W ⊕ V, fold the incoming as the
        // earlier operand. Rank 0's W stays empty for the whole run, so it
        // keeps sending its bare input (its inclusive partial *is* V_0)
        // and never pays a ⊕. Send/recv activity are both monotone in k,
        // so a rank is done once neither port is active. ──
        let mut k = 1u32;
        let mut s = 2usize;
        loop {
            let send = r + s < p;
            let recv = r >= s;
            match (send, recv) {
                (true, true) => {
                    let mut w_prime = ctx.scratch_from(input);
                    ctx.reduce_local(k, op, output, &mut w_prime);
                    ctx.sendrecv_reduce_into(k, r + s, &w_prime, r - s, op, output)?;
                }
                (true, false) if r == 0 => ctx.send(k, r + s, input)?,
                (true, false) => {
                    let mut w_prime = ctx.scratch_from(input);
                    ctx.reduce_local(k, op, output, &mut w_prime);
                    ctx.send(k, r + s, &w_prime)?;
                }
                (false, true) => ctx.recv_reduce(k, r - s, op, output)?,
                (false, false) => break,
            }
            k += 1;
            s *= 2;
        }
        Ok(())
    }

    /// `⌈log₂ p⌉` — the one-ported round lower bound, met exactly.
    fn predicted_rounds(&self, p: usize) -> u32 {
        rounds_pow2(p)
    }

    /// `K − 1` ⊕ on the completion-critical rank `p−1`: it folds one
    /// incoming partial per round except round 0 (a copy).
    fn predicted_ops(&self, p: usize) -> u32 {
        rounds_pow2(p).saturating_sub(1)
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        // Rank p-1 receives every round: distances 1, 2, 4, …, 2^(K-1).
        (0..rounds_pow2(p)).map(|k| 1usize << k).collect()
    }

    /// Selection prices the sender-side fortification honestly: each
    /// critical-path arrival was preceded by the sender's own `W ⊕ V`
    /// preparation, which serializes on the same dependency chain. So the
    /// schedule carries `2(K−1)` ⊕ even though the critical *rank's* trace
    /// shows `K−1` — otherwise pow2 would falsely dominate 123-doubling
    /// at large m, where its extra ⊕ volume is exactly what 123 avoids.
    fn critical_schedule(&self, p: usize, m: usize) -> (Vec<usize>, u32, usize) {
        let k = rounds_pow2(p);
        (
            <Self as ScanAlgorithm<T>>::critical_skips(self, p),
            2 * k.saturating_sub(1),
            m,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};
    use crate::util::bits::rounds_123;

    #[test]
    fn matches_oracle_exhaustive_small_p() {
        for p in 2usize..=40 {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| vec![(r as i64).wrapping_mul(0x9E37_79B9) ^ 0x0F0F, 1 << (r % 60)])
                .collect();
            let res = run_scan(&cfg, &ExscanPow2, &ops::bxor(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        }
    }

    #[test]
    fn closed_form_rounds_and_ops() {
        for p in 2usize..=70 {
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
            let res = run_scan(&cfg, &ExscanPow2, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            let algo: &dyn ScanAlgorithm<i64> = &ExscanPow2;
            let k = algo.predicted_rounds(p);
            assert_eq!(trace.total_rounds(), k, "rounds p={p}");
            assert_eq!(trace.last_rank_ops(), algo.predicted_ops(p), "last-rank ops p={p}");
            // Middle ranks pay at most 2 ⊕ per fortified round.
            assert!(trace.max_ops() <= 2 * k.saturating_sub(1), "max ops bound p={p}");
            assert!(crate::trace::check_all(&trace).is_empty(), "invariants p={p}");
        }
    }

    #[test]
    fn meets_round_lower_bound_beating_123() {
        let algo: &dyn ScanAlgorithm<i64> = &ExscanPow2;
        // p = 256: 8 rounds, one fewer than 123-doubling's 9.
        assert_eq!(algo.predicted_rounds(256), 8);
        assert_eq!(rounds_123(256), 9);
        // And never more rounds than 123 anywhere.
        for p in 2usize..=4096 {
            assert!(algo.predicted_rounds(p) <= rounds_123(p), "p={p}");
        }
    }

    #[test]
    fn rank0_never_receives_or_reduces_under_chaos() {
        use crate::mpi::ChaosConfig;
        use crate::trace::EventKind;
        for p in 2usize..=6 {
            for seed in [11u64, 12, 13] {
                let cfg = WorldConfig::new(Topology::flat(p))
                    .with_trace(true)
                    .with_chaos(ChaosConfig::new(seed ^ ((p as u64) << 8)));
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| vec![(r as i64 + 7) * 5, !(r as i64)]).collect();
                let res = run_scan(&cfg, &ExscanPow2, &ops::bxor(), &inputs).unwrap();
                assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
                let trace = res.trace.unwrap();
                let algo: &dyn ScanAlgorithm<i64> = &ExscanPow2;
                let k = algo.predicted_rounds(p);
                assert_eq!(trace.total_rounds(), k, "rounds p={p} seed={seed}");
                assert!(crate::trace::check_all(&trace).is_empty(), "invariants p={p} seed={seed}");
                // Rank 0 sends its bare input every round and never folds.
                let r0 = &trace.traces[0];
                assert!(
                    r0.events.iter().all(|e| !matches!(e.kind, EventKind::Recv { .. })),
                    "rank 0 must not receive, p={p} seed={seed}"
                );
                assert_eq!(r0.ops(), 0, "rank 0 must not reduce, p={p} seed={seed}");
                assert_eq!(r0.comm_rounds(), k, "rank 0 sends in every round, p={p} seed={seed}");
            }
        }
    }

    #[test]
    fn noncommutative_order() {
        use crate::coll::validate::oracle_exscan;
        use crate::mpi::Rec2;
        for p in [3usize, 5, 9, 16, 27] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<Rec2>> = (0..p)
                .map(|r| {
                    vec![Rec2::new(
                        [1.0, 0.03 * r as f32, -0.02 * r as f32, 1.0],
                        [r as f32 * 0.25, 1.0 - r as f32 * 0.5],
                    )]
                })
                .collect();
            let res = run_scan(&cfg, &ExscanPow2, &ops::rec2_compose(), &inputs).unwrap();
            let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
            for r in 1..p {
                let e = oracle[r].as_ref().unwrap();
                for i in 0..4 {
                    assert!(
                        (res.outputs[r][0].a[i] - e[0].a[i]).abs() < 1e-3,
                        "p={p} r={r} a[{i}]"
                    );
                }
            }
        }
    }

    #[test]
    fn multi_element_vectors() {
        let p = 21;
        for m in [0usize, 1, 2, 17, 256] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| (0..m).map(|i| (r * 29 + i * 11) as i64).collect())
                .collect();
            let res = run_scan(&cfg, &ExscanPow2, &ops::sum_i64(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
        }
    }
}
