//! Block-decomposed exclusive scan — the paper's "for large input
//! vectors, other algorithms must be used" regime, built **around** the
//! round-optimal engine instead of replacing it.
//!
//! The world splits into `p/g` **groups** of `g` consecutive ranks, and
//! the m-vector into `g` element blocks; member `j` of each group owns
//! block `j` (SNIPPETS.md snippet 2's scatter → local-scan → allgather
//! shape, generalized from scalar `+` to every registered ⊕ and to the
//! pooled one-ported transport):
//!
//! 1. **Group transpose**: `g−1` cyclic in-group steps; member `j`
//!    collects every group member's slice of block `j` (`m/g` elements
//!    per message).
//! 2. **Local scan**: one [`scan_rows`](crate::mpi::RankCtx::scan_rows)
//!    launch promotes the `g` rows to group-local inclusive prefixes
//!    (tight-loop kernels, `g−1` ⊕ at block width); row `g−1` is the
//!    group **total**.
//! 3. **Inner exscan**: member `j` of every group runs the shared
//!    round-optimal [`exscan_123_group`](super::exscan_123) engine over
//!    the group totals of block `j` — `rounds_123(p/g)` rounds of
//!    `m/g`-element messages, the same Theorem-1 schedule as the flat
//!    algorithm but on vectors `g×` smaller. The per-block participant
//!    sets are disjoint (ranks ≡ j mod g), so all `g` inner scans run
//!    concurrently in the same rounds, each on its own
//!    [`TagKey`](crate::mpi::TagKey) lane.
//! 4. **Fused apply + return**: one slice pass folds the inner prefix
//!    into the local rows (`g−1` ⊕), then `g−1` cyclic steps return
//!    each rank's finished `W` block.
//!
//! Cost: `2(g−1) + q(p/g)` rounds of `m/g`-element messages — the knob
//! `g` trades α-rounds for β-bandwidth. `g = 1` **is** the flat
//! 123-doubling (phases 1/2/4 vanish); `g = p` is the pure column-owner
//! scheme (cf. [`ExscanRsag`](super::ExscanRsag), which additionally
//! drops rank `p−1`'s unused vector). [`ExscanBlock::auto`] resolves `g`
//! per `(p, m)` as the closed-form α-β-γ argmin over the divisors of
//! `p`, with the **same** pure function used by `run` and
//! [`critical_schedule`](ScanAlgorithm::critical_schedule), so the
//! prediction always prices the schedule that actually executes.

use anyhow::Result;

use super::exscan_123::exscan_123_group;
use super::exscan_rsag::block_range;
use super::{Exscan123, ScanAlgorithm, ScanKind};
use crate::cost::{predict_flat, CostParams};
use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::bits::rounds_123;

/// Block-decomposed exclusive scan with a group-width policy.
pub struct ExscanBlock {
    /// Requested group width, or `None` to auto-select the cost-model
    /// argmin over the divisors of `p` per `(p, m)`.
    pub group: Option<usize>,
}

impl ExscanBlock {
    /// Cost-model auto-selected group width.
    pub fn auto() -> Self {
        ExscanBlock { group: None }
    }

    /// Fixed group-width request (≥ 1); snapped down to the largest
    /// divisor of `p` at run time, so any request degrades gracefully.
    pub fn with_group(g: usize) -> Self {
        assert!(g >= 1);
        ExscanBlock { group: Some(g) }
    }

    /// The group width actually used for `(p, m)` and the element size —
    /// a pure function shared by `run`, the closed forms and the
    /// prediction schedule (they must never disagree).
    pub fn group_for(&self, p: usize, m: usize, elem_bytes: usize) -> usize {
        if p <= 1 {
            return 1;
        }
        match self.group {
            Some(g) => largest_divisor_at_most(p, g.min(p)),
            None => auto_group(p, m, elem_bytes),
        }
    }

    /// Exact round count: `2(g−1) + rounds_123(p/g)`.
    pub fn rounds_for(&self, p: usize, m: usize, elem_bytes: usize) -> u32 {
        if p <= 1 {
            return 0;
        }
        let g = self.group_for(p, m, elem_bytes);
        2 * (g as u32 - 1) + rounds_123(p / g)
    }

    /// ⊕ applications on the completion-critical rank `p−1`: the local
    /// scan (`g−1`), the inner exscan's critical count (`q−1`) and the
    /// fused prefix apply (`g−1`) — m-independent by construction.
    pub fn ops_for(&self, p: usize, m: usize, elem_bytes: usize) -> u32 {
        if p <= 1 {
            return 0;
        }
        let g = self.group_for(p, m, elem_bytes);
        last_ops_for_group(g, p / g)
    }

    /// Upper bound on any rank's ⊕ count: `2(g−1) + q` (middle inner
    /// participants pay one extra ⊕ for the round-1 send preparation).
    pub fn max_ops_for(&self, p: usize, m: usize, elem_bytes: usize) -> u32 {
        if p <= 1 {
            return 0;
        }
        let g = self.group_for(p, m, elem_bytes);
        2 * (g as u32 - 1) + rounds_123(p / g)
    }
}

/// Largest divisor of `p` that is ≤ `cap` (≥ 1).
fn largest_divisor_at_most(p: usize, cap: usize) -> usize {
    (1..=cap.max(1)).rev().find(|d| p % d == 0).unwrap_or(1)
}

/// Critical-path ⊕ count for a concrete group width.
fn last_ops_for_group(g: usize, n_g: usize) -> u32 {
    let gm1 = (g - 1) as u32;
    if n_g >= 2 {
        gm1 + (rounds_123(n_g) - 1) + gm1
    } else {
        gm1
    }
}

/// The `(skips, critical ⊕, elements per message)` schedule for a
/// concrete group width — what `critical_schedule` reports and what the
/// auto-selection prices.
pub(crate) fn schedule_for_group(p: usize, g: usize, m: usize) -> (Vec<usize>, u32, usize) {
    let n_g = p / g;
    let mut skips: Vec<usize> = (1..g).collect(); // group transpose (intra)
    for s in Exscan123.critical_skips_nodes(n_g) {
        skips.push(s * g); // inner hops are group-distance × g ranks
    }
    skips.extend(1..g); // return steps (intra)
    (skips, last_ops_for_group(g, n_g), m.div_ceil(g))
}

/// Closed-form α-β-γ argmin over the divisors of `p` (ties → smaller g,
/// i.e. fewer rounds). Priced with [`CostParams::generic`] at one rank
/// per node — a fixed, deterministic yardstick so the auto policy does
/// not depend on any caller-supplied model; callers who want the
/// cross-over under *calibrated* parameters go through
/// [`select_exscan`](super::select_exscan), which ranks the resulting
/// schedule against every other algorithm under the real params.
fn auto_group(p: usize, m: usize, elem_bytes: usize) -> usize {
    let params = CostParams::generic();
    let mut best = (f64::INFINITY, 1usize);
    for g in 1..=p {
        if p % g != 0 {
            continue;
        }
        let (skips, ops, msg_elems) = schedule_for_group(p, g, m);
        let pred = predict_flat(&skips, ops, p, 1, msg_elems * elem_bytes, &params);
        if pred.time_us < best.0 {
            best = (pred.time_us, g);
        }
    }
    best.1
}

impl<T: Elem> ScanAlgorithm<T> for ExscanBlock {
    fn name(&self) -> &'static str {
        "block-exscan"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p, m) = (ctx.rank(), ctx.size(), input.len());
        if p <= 1 {
            return Ok(());
        }
        let op = &ctx.kernel(op);
        let g = self.group_for(p, m, T::size_bytes());
        let n_g = p / g;
        let gi = r / g; // group index
        let j = r % g; // member index == owned element block
        let first = gi * g; // first rank of this group
        let my = block_range(m, g, j);
        let w = my.len();

        // Rows of this member's owned block, group-member-major i = 0..g−1.
        let mut rows = vec![T::filler(); g * w];
        rows[j * w..(j + 1) * w].copy_from_slice(&input[my.clone()]);

        // ── Phase 1: in-group cyclic transpose (g−1 steps, one lane per
        // step). Every member both sends and receives every step — the
        // owner needs all g rows, including the last member's, for the
        // group total. ──
        for k in 1..g {
            let round = (k - 1) as u32;
            let t = (j + k) % g;
            let f = (j + g - k) % g;
            ctx.with_chunk(k as u16, |c| {
                let rrow = &mut rows[f * w..];
                c.sendrecv(
                    round,
                    first + t,
                    &input[block_range(m, g, t)],
                    first + f,
                    &mut rrow[..w],
                )
            })?;
        }

        // ── Phase 2: one scan launch — row i becomes the group-local
        // inclusive prefix through member i (g−1 ⊕ at block width). ──
        let base2 = (g - 1) as u32;
        ctx.scan_rows(base2, op, &mut rows, w, g);

        // ── Phase 3: inner round-optimal exscan over the group totals of
        // this block. Participants are member j of every group (disjoint
        // sets per block ⇒ all g inner scans share the same rounds, each
        // on its own lane). `prefix` = ⊕ of all earlier groups' totals. ──
        let mut prefix = ctx.scratch_filled(w);
        let have_prefix = if n_g >= 2 {
            let participants: Vec<usize> = (0..n_g).map(|gg| gg * g + j).collect();
            ctx.with_chunk(j as u16, |c| {
                exscan_123_group(c, base2, &participants, op, &rows[(g - 1) * w..], &mut prefix)
            })?
        } else {
            false
        };

        // ── Phase 4: fused prefix apply — fold the earlier-groups prefix
        // into rows 0..g−2 (row i then holds W for in-group target i+1;
        // target 0's W is `prefix` itself), then g−1 cyclic return steps.
        // Round bases are uniform across ranks: phases 1/3/4 use the
        // disjoint ranges [0, g−1), [g−1, g−1+q), [g−1+q, 2(g−1)+q). ──
        let base3 = base2 + rounds_123(n_g);
        if have_prefix {
            for i in 0..g - 1 {
                ctx.reduce_local(base3, op, &prefix, &mut rows[i * w..(i + 1) * w]);
            }
        }
        for k in 1..g {
            let round = base3 + (k - 1) as u32;
            let t = (j + k) % g;
            let f = (j + g - k) % g;
            let send_active = !(gi == 0 && t == 0); // world rank 0: W undefined
            let recv_active = !(gi == 0 && j == 0);
            ctx.with_chunk(k as u16, |c| {
                let sbuf: &[T] = if t >= 1 { &rows[(t - 1) * w..t * w] } else { &prefix };
                let dst = block_range(m, g, f);
                match (send_active, recv_active) {
                    (true, true) => {
                        c.sendrecv(round, first + t, sbuf, first + f, &mut output[dst])
                    }
                    (true, false) => c.send(round, first + t, sbuf),
                    (false, true) => c.recv(round, first + f, &mut output[dst]),
                    (false, false) => Ok(()),
                }
            })?;
        }
        if j >= 1 {
            output[my].copy_from_slice(&rows[(j - 1) * w..j * w]);
        } else if have_prefix {
            output[my].copy_from_slice(&prefix);
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        // Depends on m via the group width; report the g = 1 envelope
        // (callers needing the exact count use `rounds_for(p, m, …)`).
        rounds_123(p)
    }

    /// m-aware round count — what the trace measures.
    fn predicted_rounds_m(&self, p: usize, m: usize) -> u32 {
        self.rounds_for(p, m, T::size_bytes())
    }

    fn predicted_ops(&self, p: usize) -> u32 {
        rounds_123(p).saturating_sub(1) // g = 1 envelope
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        Exscan123.critical_skips_nodes(p) // g = 1 envelope
    }

    /// The honest m-aware schedule for the group width `run` would use.
    fn critical_schedule(&self, p: usize, m: usize) -> (Vec<usize>, u32, usize) {
        if p <= 1 {
            return (vec![], 0, m);
        }
        let g = self.group_for(p, m, T::size_bytes());
        schedule_for_group(p, g, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle_over_divisor_grid() {
        for p in [2usize, 4, 6, 8, 9, 12] {
            for g in 1..=p {
                if p % g != 0 {
                    continue;
                }
                for m in [0usize, 1, 5, 64] {
                    let algo = ExscanBlock::with_group(g);
                    let cfg = WorldConfig::new(Topology::flat(p));
                    let inputs: Vec<Vec<i64>> = (0..p)
                        .map(|r| (0..m).map(|i| ((r * 131 + i * 17) as i64) ^ 0x0F0F).collect())
                        .collect();
                    let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
                    assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
                }
            }
        }
    }

    #[test]
    fn non_divisor_requests_snap_down() {
        // p = 12, requested 5 → effective 4; p = 7 (prime), requested 4 →
        // effective 1 (degenerates to the flat 123 schedule).
        assert_eq!(ExscanBlock::with_group(5).group_for(12, 100, 8), 4);
        assert_eq!(ExscanBlock::with_group(4).group_for(7, 100, 8), 1);
        assert_eq!(ExscanBlock::with_group(100).group_for(6, 100, 8), 6);
        for (p, req) in [(12usize, 5usize), (7, 4), (10, 9)] {
            let algo = ExscanBlock::with_group(req);
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| (0..21).map(|i| (r * 31 + i * 7) as i64).collect()).collect();
            let res = run_scan(&cfg, &algo, &ops::sum_i64(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
        }
    }

    #[test]
    fn closed_form_rounds_and_ops() {
        for p in [2usize, 4, 6, 8, 9, 12, 16] {
            for g in 1..=p {
                if p % g != 0 {
                    continue;
                }
                let algo = ExscanBlock::with_group(g);
                let m = 24;
                let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| (0..m).map(|i| (r * 7 + i) as i64).collect()).collect();
                let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
                let trace = res.trace.unwrap();
                let eb = 8; // i64
                assert_eq!(
                    trace.total_rounds(),
                    algo.rounds_for(p, m, eb),
                    "rounds p={p} g={g}"
                );
                assert_eq!(
                    trace.last_rank_ops(),
                    algo.ops_for(p, m, eb),
                    "last-rank ops p={p} g={g}"
                );
                assert!(
                    trace.max_ops() <= algo.max_ops_for(p, m, eb),
                    "max ops p={p} g={g}: {} > {}",
                    trace.max_ops(),
                    algo.max_ops_for(p, m, eb)
                );
                assert!(crate::trace::check_all(&trace).is_empty(), "invariants p={p} g={g}");
            }
        }
    }

    #[test]
    fn ops_are_m_independent() {
        for m in [0usize, 1, 2, 31] {
            let (p, g) = (8usize, 4usize);
            let algo = ExscanBlock::with_group(g);
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64; m]).collect();
            let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            assert_eq!(trace.total_rounds(), algo.rounds_for(p, m, 8), "m={m}");
            assert_eq!(trace.last_rank_ops(), algo.ops_for(p, m, 8), "m={m}");
        }
    }

    #[test]
    fn auto_group_scales_with_m_and_matches_run() {
        // Small m → round count dominates → g = 1 (the flat schedule);
        // large m → bandwidth dominates → g grows. And the traced run
        // must match the closed form for the SAME auto-resolved g.
        let algo = ExscanBlock::auto();
        assert_eq!(algo.group_for(8, 1, 8), 1, "tiny m keeps the round-optimal g=1");
        let g_large = algo.group_for(8, 1_000_000, 8);
        assert!(g_large > 1, "large m must widen the group, got {g_large}");
        for m in [1usize, 512, 65_536] {
            let p = 8;
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| (0..m).map(|i| (r * 13 + i) as i64).collect()).collect();
            let res = run_scan(&cfg, &algo, &ops::sum_i64(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
            let trace = res.trace.unwrap();
            assert_eq!(trace.total_rounds(), algo.rounds_for(p, m, 8), "m={m}");
        }
    }

    #[test]
    fn noncommutative_order() {
        use crate::coll::validate::oracle_exscan;
        use crate::mpi::Rec2;
        for (p, g) in [(9usize, 3usize), (8, 4), (6, 2), (12, 6)] {
            let m = 7; // ragged blocks
            let algo = ExscanBlock::with_group(g);
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<Rec2>> = (0..p)
                .map(|r| {
                    (0..m)
                        .map(|i| {
                            Rec2::new(
                                [1.0, 0.02 * r as f32, -0.01 * i as f32, 1.0],
                                [r as f32 * 0.5, 1.0 - i as f32 * 0.25],
                            )
                        })
                        .collect()
                })
                .collect();
            let res = run_scan(&cfg, &algo, &ops::rec2_compose(), &inputs).unwrap();
            let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
            for r in 1..p {
                let e = oracle[r].as_ref().unwrap();
                for (a, b) in res.outputs[r].iter().zip(e) {
                    for i in 0..4 {
                        assert!((a.a[i] - b.a[i]).abs() < 1e-3, "p={p} g={g} r={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn chaos_reordering_is_bit_identical() {
        use crate::mpi::ChaosConfig;
        for (p, g) in [(4usize, 2usize), (8, 4), (9, 3), (6, 6)] {
            for seed in [1u64, 2, 3] {
                let algo = ExscanBlock::with_group(g);
                let cfg = WorldConfig::new(Topology::flat(p))
                    .with_trace(true)
                    .with_chaos(ChaosConfig::new(seed ^ ((p as u64) << 8) ^ (g as u64)));
                let inputs: Vec<Vec<i64>> = (0..p)
                    .map(|r| (0..9).map(|i| ((r + 2) * (i + 5)) as i64).collect())
                    .collect();
                let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
                assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
                let trace = res.trace.unwrap();
                assert!(
                    crate::trace::check_all(&trace).is_empty(),
                    "invariants p={p} g={g} seed={seed}"
                );
            }
        }
    }
}
