//! **Two-level topology-aware exclusive scan**: leaders run the
//! round-optimal [`Exscan123`] *across* node groups while members
//! resolve intra-node over the cheap links — the optimization the
//! hierarchical-network analysis leaves open and [`crate::topo`] makes
//! measurable.
//!
//! Ranks are block-grouped by `ppn` (group `j` = scope ranks
//! `[j·ppn, min((j+1)·ppn, p))`, ragged last group allowed; matches
//! [`crate::topo::Topo::node_of`]), each group's first member is its
//! leader. Four phases, all on reserved sub-communicator contexts so
//! nothing collides with the ambient scope:
//!
//! 1. **Intra-node exscan** — every group runs [`Exscan123`] on its own
//!    node communicator: member `i` of group `j` holds
//!    `W = V_{lo} ⊕ … ⊕ V_{lo+i−1}` (`lo = j·ppn`).
//! 2. **Node totals** — each group's *last* member computes
//!    `total_j = W ⊕ V` (one ⊕) and sends it to its leader (a plain
//!    receive; a singleton group's total is just its input).
//! 3. **Leader exscan** — leaders run [`Exscan123`] over the totals on
//!    the leader communicator (the only inter-node phase:
//!    `rounds_123(G)` expensive hops). Leader `j > 0` receives
//!    `P_j = total_0 ⊕ … ⊕ total_{j−1}` **directly into its main output**
//!    — exactly its exscan value; leader 0's output stays untouched,
//!    per MPI_Exscan.
//! 4. **Broadcast + fold** — leader `j > 0` broadcasts `P_j` down its
//!    group (binomial, intra-node); member `i > 0` folds it as the
//!    *earlier* operand into its phase-1 `W`. Group 0 skips both.
//!
//! All groups share ONE node context id (disjoint rank sets cannot
//! cross-match; message keys carry the source rank), so the traced
//! global round count is the *union* of per-group round indices — the
//! round plan [`two_level_rounds`] states in closed form — plus the
//! leader phase, not a per-group sum. No world [`barrier`] is used
//! anywhere (it is world-wide; this code is group-divergent).
//!
//! Closed forms (checked against traces): rounds = [`two_level_rounds`];
//! the completion-critical rank `p−1` applies [`two_level_ops`] ⊕
//! (`rounds_123(k_last) + 1` in the common case: its phase-1 count plus
//! the total preparation plus the final fold); no rank exceeds
//! `rounds_123(ppn) + rounds_123(G) + 2`.
//!
//! [`Exscan123`]: super::Exscan123
//! [`barrier`]: crate::mpi::RankCtx::barrier

use anyhow::Result;

use super::basic::bcast;
use super::exscan_123::Exscan123;
use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Comm, Elem, OpRef, RankCtx};
use crate::util::bits::rounds_123;
use crate::util::ceil_log2;

/// Closed-form global round count of the two-level scheme at group width
/// `ppn`: the union of every group's node-context round indices (each
/// group uses the prefix `{0 .. r123(k_j)}`, groups `j > 0` extend it by
/// their `⌈log₂ k_j⌉` broadcast rounds) plus the `rounds_123(G)` leader
/// rounds. Degenerate shapes collapse: one group → plain `rounds_123(p)`;
/// all-singleton groups → pure leader exscan.
pub fn two_level_rounds(ppn: usize, p: usize) -> u32 {
    assert!(ppn >= 1);
    if p <= 1 {
        return 0;
    }
    let g = p.div_ceil(ppn);
    if g == 1 {
        return rounds_123(p);
    }
    let mut node_max = 0u32;
    for j in 0..g {
        let lo = j * ppn;
        let kj = ppn.min(p - lo);
        if kj <= 1 {
            continue; // singleton group: no node-context traffic at all
        }
        // Phase-1 rounds 0..r123(kj)-1, the totals hop at r123(kj)…
        let mut top = rounds_123(kj) + 1;
        // …and for j > 0 the broadcast rounds stacked after it.
        if j > 0 {
            top += ceil_log2(kj);
        }
        node_max = node_max.max(top);
    }
    node_max + rounds_123(g)
}

/// Closed-form ⊕ count on the completion-critical rank `p−1`.
pub fn two_level_ops(ppn: usize, p: usize) -> u32 {
    assert!(ppn >= 1);
    if p <= 1 {
        return 0;
    }
    let g = p.div_ceil(ppn);
    if g == 1 {
        return rounds_123(p).saturating_sub(1);
    }
    let kl = p - (g - 1) * ppn;
    if kl == 1 {
        // Rank p−1 is the last leader: its leader-phase receives only
        // (the first is a copy), no total prep, no final fold.
        rounds_123(g).saturating_sub(1)
    } else {
        // Phase-1 last-rank count + the total preparation + the fold of
        // the broadcast prefix.
        rounds_123(kl) + 1
    }
}

/// Safe upper bound on any rank's ⊕ count (leaders pay the leader-phase
/// fortification, members the total prep and final fold).
pub fn two_level_max_ops(ppn: usize, p: usize) -> u32 {
    let g = p.div_ceil(ppn.max(1));
    rounds_123(ppn.min(p)) + rounds_123(g) + 2
}

/// Two-level topology-aware exclusive scan (leaders bridge node groups).
pub struct ExscanTwoLevel {
    ppn: usize,
}

impl ExscanTwoLevel {
    /// Group width (ranks per node). Pair it with the matching
    /// [`crate::topo::Topo`] preset so the grouping and the link matrix
    /// agree (`ExscanTwoLevel::new(topo.ranks_per_node())`).
    pub fn new(ppn: usize) -> Self {
        assert!(ppn >= 1, "ranks-per-node must be >= 1");
        ExscanTwoLevel { ppn }
    }

    pub fn ppn(&self) -> usize {
        self.ppn
    }
}

impl<T: Elem> ScanAlgorithm<T> for ExscanTwoLevel {
    fn name(&self) -> &'static str {
        "two-level"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p) = (ctx.rank(), ctx.size());
        if p <= 1 {
            return Ok(());
        }
        let ppn = self.ppn;
        let g = p.div_ceil(ppn);
        if g == 1 {
            // One group: the leader scheme degenerates to the flat
            // round-optimal algorithm on the ambient scope.
            return Exscan123.run(ctx, input, output, op);
        }

        // Reserved sub-communicator contexts, derived from the ambient
        // scope so concurrent two-level runs on different communicators
        // stay match-isolated. CtxAlloc hands out ids from 1 upward, so
        // the 0x8000+ range is free until ~32k live communicators.
        let ambient = ctx.ctx_id();
        assert!(
            ambient < 0x80,
            "two-level reserves contexts 0x8000+ per ambient ctx; ambient {ambient} too large"
        );
        let leader_ctx: u16 = 0x8000 + ambient * 0x100;
        let node_ctx: u16 = leader_ctx + 1;

        let j = r / ppn;
        let lo = j * ppn;
        let kj = ppn.min(p - lo);
        let q_k = rounds_123(kj);

        // ONE shared node context for all (disjoint) groups: message keys
        // carry the source rank, so groups cannot cross-match, and the
        // traced global round count stays the union of the groups' round
        // indices instead of a per-group sum.
        let group: Vec<usize> = (lo..lo + kj).map(|i| ctx.scope_world_rank(i)).collect();
        let node_comm = Comm::new(node_ctx, group);
        let opk = ctx.kernel(op);

        // ── Phase 1: intra-node exscan (node rounds 0 .. q_k−1). ──
        ctx.with_comm(&node_comm, |c| Exscan123.run(c, input, output, op))?;

        if r == lo {
            // ── Leader: collect the node total, bridge the groups, then
            // broadcast the group prefix back down. ──
            let mut total = ctx.scratch_from(input); // k_j == 1: total = V
            if kj > 1 {
                ctx.with_comm(&node_comm, |c| c.recv(q_k, kj - 1, &mut total))?;
            }
            let leaders: Vec<usize> = (0..g).map(|jj| ctx.scope_world_rank(jj * ppn)).collect();
            let leader_comm = Comm::new(leader_ctx, leaders);
            // ── Phase 3 (leader rounds 0 .. r123(G)−1): P_j lands
            // directly in the main output — it IS leader j's exscan
            // value; leader 0's output stays untouched. ──
            ctx.with_comm(&leader_comm, |c| Exscan123.run(c, &total, output, op))?;
            if j > 0 && kj > 1 {
                ctx.with_comm(&node_comm, |c| bcast(c, q_k + 1, 0, output).map(|_| ()))?;
            }
        } else {
            if r == lo + kj - 1 {
                // ── Phase 2 (node round q_k): last member prepares
                // total_j = W ⊕ V (W is the earlier operand) and ships it
                // to the leader. ──
                let mut total = ctx.scratch_from(input);
                ctx.with_comm(&node_comm, |c| {
                    c.reduce_local(q_k, &opk, output, &mut total);
                    c.send(q_k, 0, &total)
                })?;
            }
            if j > 0 {
                // ── Phase 4 (node rounds q_k+1 ..): receive P_j and fold
                // it as the earlier operand into the phase-1 W. Group 0's
                // members already hold their final value. ──
                let mut pfx = ctx.scratch_from(input);
                ctx.with_comm(&node_comm, |c| {
                    bcast(c, q_k + 1, 0, &mut pfx)?;
                    c.reduce_local(q_k + ceil_log2(kj), &opk, &pfx, output);
                    Ok(())
                })?;
            }
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        two_level_rounds(self.ppn, p)
    }

    fn predicted_ops(&self, p: usize) -> u32 {
        two_level_ops(self.ppn, p)
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        // Flat-model approximation of the critical dependency chain (the
        // topology-aware predictor prices the phases off the link matrix
        // instead — `cost::predict::predict_two_level`): phase-1 receive
        // distances inside the last group (intra), the leader hops scaled
        // by the group width (inter), and the binomial broadcast hops
        // back down (intra).
        let ppn = self.ppn;
        if p <= 1 {
            return Vec::new();
        }
        let g = p.div_ceil(ppn);
        if g == 1 {
            return <Exscan123 as ScanAlgorithm<T>>::critical_skips(&Exscan123, p);
        }
        let kl = p - (g - 1) * ppn;
        let mut skips = Vec::new();
        if kl > 1 {
            skips.extend(<Exscan123 as ScanAlgorithm<T>>::critical_skips(&Exscan123, kl));
            skips.push(kl - 1); // totals hop to the leader
        }
        for s in <Exscan123 as ScanAlgorithm<T>>::critical_skips(&Exscan123, g) {
            skips.push(s * ppn); // leader hops span whole groups
        }
        if kl > 1 {
            for i in 0..ceil_log2(kl) {
                skips.push(1usize << i); // binomial broadcast back down
            }
        }
        skips
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle_exhaustive_small_p() {
        for ppn in [1usize, 2, 3, 4, 5, 8] {
            for p in 2usize..=40 {
                let cfg = WorldConfig::new(Topology::flat(p));
                let inputs: Vec<Vec<i64>> = (0..p)
                    .map(|r| vec![(r as i64).wrapping_mul(0x6C62_272E) ^ 0xA5A5, 1 << (r % 60)])
                    .collect();
                let res =
                    run_scan(&cfg, &ExscanTwoLevel::new(ppn), &ops::bxor(), &inputs).unwrap();
                assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
            }
        }
    }

    #[test]
    fn closed_form_rounds_and_ops() {
        for ppn in [1usize, 2, 3, 4, 7] {
            for p in 2usize..=40 {
                let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
                let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
                let algo = ExscanTwoLevel::new(ppn);
                let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
                let trace = res.trace.unwrap();
                let a: &dyn ScanAlgorithm<i64> = &algo;
                assert_eq!(
                    trace.total_rounds(),
                    a.predicted_rounds(p),
                    "rounds ppn={ppn} p={p}"
                );
                assert_eq!(
                    trace.last_rank_ops(),
                    a.predicted_ops(p),
                    "last-rank ops ppn={ppn} p={p}"
                );
                assert!(
                    trace.max_ops() <= two_level_max_ops(ppn, p),
                    "max ops bound ppn={ppn} p={p}"
                );
                assert!(
                    crate::trace::check_all(&trace).is_empty(),
                    "invariants ppn={ppn} p={p}"
                );
            }
        }
    }

    #[test]
    fn degenerate_shapes_collapse() {
        // One group: identical round/⊕ counts to plain 123-doubling.
        for p in 2usize..=8 {
            assert_eq!(two_level_rounds(8, p), rounds_123(p), "p={p}");
            assert_eq!(two_level_ops(8, p), rounds_123(p).saturating_sub(1), "p={p}");
        }
        // All-singleton groups: a pure leader exscan.
        for p in 2usize..=16 {
            assert_eq!(two_level_rounds(1, p), rounds_123(p), "p={p}");
        }
        // The paper-shaped 4x9 cluster: 4 node rounds + 1 totals hop +
        // leader exscan over 4 + 4 broadcast rounds... stated exactly.
        let expect = rounds_123(9) + 1 + ceil_log2(9) + rounds_123(4);
        assert_eq!(two_level_rounds(9, 36), expect);
        assert_eq!(two_level_ops(9, 36), rounds_123(9) + 1);
    }

    #[test]
    fn chaos_differential_at_fixed_seeds() {
        use crate::mpi::ChaosConfig;
        for ppn in [2usize, 3, 4] {
            for p in [5usize, 9, 12, 17] {
                for seed in [31u64, 32, 33] {
                    let cfg = WorldConfig::new(Topology::flat(p))
                        .with_trace(true)
                        .with_chaos(ChaosConfig::new(seed ^ ((p as u64) << 8) ^ (ppn as u64)));
                    let inputs: Vec<Vec<i64>> =
                        (0..p).map(|r| vec![(r as i64 + 13) * 7, !(r as i64)]).collect();
                    let algo = ExscanTwoLevel::new(ppn);
                    let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
                    assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
                    let trace = res.trace.unwrap();
                    let a: &dyn ScanAlgorithm<i64> = &algo;
                    assert_eq!(
                        trace.total_rounds(),
                        a.predicted_rounds(p),
                        "rounds ppn={ppn} p={p} seed={seed}"
                    );
                    assert!(
                        crate::trace::check_all(&trace).is_empty(),
                        "invariants ppn={ppn} p={p} seed={seed}"
                    );
                }
            }
        }
    }

    #[test]
    fn noncommutative_order() {
        use crate::coll::validate::oracle_exscan;
        use crate::mpi::Rec2;
        for (p, ppn) in [(9usize, 3usize), (12, 4), (14, 4), (27, 9)] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<Rec2>> = (0..p)
                .map(|r| {
                    vec![Rec2::new(
                        [1.0, 0.02 * r as f32, -0.015 * r as f32, 1.0],
                        [r as f32 * 0.3, 1.0 - r as f32 * 0.35],
                    )]
                })
                .collect();
            let res =
                run_scan(&cfg, &ExscanTwoLevel::new(ppn), &ops::rec2_compose(), &inputs).unwrap();
            let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
            for r in 1..p {
                let e = oracle[r].as_ref().unwrap();
                for i in 0..4 {
                    assert!(
                        (res.outputs[r][0].a[i] - e[0].a[i]).abs() < 1e-3,
                        "p={p} ppn={ppn} r={r} a[{i}]"
                    );
                }
            }
        }
    }

    #[test]
    fn multi_element_vectors() {
        let (p, ppn) = (18, 5);
        for m in [0usize, 1, 2, 17, 256] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| (0..m).map(|i| (r * 41 + i * 17) as i64).collect())
                .collect();
            let res = run_scan(&cfg, &ExscanTwoLevel::new(ppn), &ops::sum_i64(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
        }
    }
}
