//! Closed-form time predictions from the α-β-γ model.
//!
//! For an algorithm with critical-path receive skips `s_0 … s_{q-1}` and
//! `n_ops` ⊕ applications, the predicted completion time on a block-placed
//! `nodes × rpn` cluster is
//!
//! ```text
//!   T(m) = Σ_k [ α(link(s_k)) + bytes·β(link(s_k)) ] + n_ops·bytes·γ + c
//! ```
//!
//! where `link(s_k)` is intra-node iff the critical rank (p−1) and its
//! round-k partner share a node. The exact per-rank interleaving is
//! captured by the trace-replay predictor ([`crate::trace::replay`]);
//! this closed form is what the algorithm-selection tuning table uses
//! (cheap, no execution needed) and what the calibration fit inverts.

use super::model::{CostParams, LinkClass};

/// Closed-form prediction summary for one (algorithm, p, m) point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlatPrediction {
    pub rounds: u32,
    pub intra_rounds: u32,
    pub inter_rounds: u32,
    pub ops: u32,
    pub time_us: f64,
}

/// Classify one critical-path round by the skip distance under block
/// placement: the critical rank is `p−1`; its partner is `p−1−s`.
pub fn skip_link(p: usize, ranks_per_node: usize, skip: usize) -> LinkClass {
    let r = p - 1;
    let partner = r.saturating_sub(skip);
    if r / ranks_per_node == partner / ranks_per_node {
        LinkClass::IntraNode
    } else {
        LinkClass::InterNode
    }
}

/// Closed-form predicted completion time.
///
/// * `skips` — the algorithm's critical-path receive distances
///   ([`crate::coll::ScanAlgorithm::critical_skips`]).
/// * `ops` — ⊕ applications on the critical path
///   ([`crate::coll::ScanAlgorithm::predicted_ops`]).
pub fn predict_flat(
    skips: &[usize],
    ops: u32,
    p: usize,
    ranks_per_node: usize,
    bytes: usize,
    params: &CostParams,
) -> FlatPrediction {
    let mut time = params.overhead;
    let mut intra = 0u32;
    let mut inter = 0u32;
    for &s in skips {
        let link = skip_link(p.max(2), ranks_per_node, s);
        match link {
            LinkClass::IntraNode => intra += 1,
            LinkClass::InterNode => inter += 1,
            LinkClass::SelfLoop => {}
        }
        time += params.alpha(link) + bytes as f64 * params.beta(link);
    }
    time += ops as f64 * bytes as f64 * params.gamma;
    FlatPrediction { rounds: skips.len() as u32, intra_rounds: intra, inter_rounds: inter, ops, time_us: time }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostParams;

    #[test]
    fn skip_link_block_placement() {
        // p = 1152, 32 ranks/node: rank 1151's partner at distance 16 is
        // 1135 — same node (both / 32 == 35). Distance 32 crosses.
        assert_eq!(skip_link(1152, 32, 16), LinkClass::IntraNode);
        assert_eq!(skip_link(1152, 32, 31), LinkClass::IntraNode);
        assert_eq!(skip_link(1152, 32, 32), LinkClass::InterNode);
        // One rank per node: everything crosses.
        assert_eq!(skip_link(36, 1, 1), LinkClass::InterNode);
    }

    #[test]
    fn prediction_composes() {
        let params = CostParams {
            alpha_intra: 1.0,
            alpha_inter: 10.0,
            beta_intra: 0.0,
            beta_inter: 0.1,
            gamma: 0.01,
            overhead: 5.0,
        };
        // Two inter rounds + one intra round at 100 bytes, 2 ops.
        let pred = predict_flat(&[32, 64, 1], 2, 128, 32, 100, &params);
        assert_eq!(pred.inter_rounds, 2);
        assert_eq!(pred.intra_rounds, 1);
        // 5 + 2*(10+10) + 1*1 + 2*100*0.01 = 5+40+1+2 = 48
        assert!((pred.time_us - 48.0).abs() < 1e-9);
    }

    #[test]
    fn more_rounds_costs_more() {
        let params = CostParams::generic();
        let a = predict_flat(&[1, 2, 4, 8, 16, 32], 5, 36, 1, 80, &params);
        let b = predict_flat(&[1, 1, 2, 4, 8, 16, 32], 6, 36, 1, 80, &params);
        assert!(b.time_us > a.time_us);
    }
}
