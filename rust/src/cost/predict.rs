//! Closed-form time predictions from the α-β-γ model.
//!
//! For an algorithm with critical-path receive skips `s_0 … s_{q-1}` and
//! `n_ops` ⊕ applications, the predicted completion time on a block-placed
//! `nodes × rpn` cluster is
//!
//! ```text
//!   T(m) = Σ_k [ α(link(s_k)) + bytes·β(link(s_k)) ] + n_ops·bytes·γ + c
//! ```
//!
//! where `link(s_k)` is intra-node iff the critical rank (p−1) and its
//! round-k partner share a node. The exact per-rank interleaving is
//! captured by the trace-replay predictor ([`crate::trace::replay`]);
//! this closed form is what the algorithm-selection tuning table uses
//! (cheap, no execution needed) and what the calibration fit inverts.
//!
//! # The two regimes and the bandwidth term
//!
//! `bytes` above is the **per-message payload**, not the vector size, so
//! the same formula prices both regimes honestly once each algorithm's
//! `critical_schedule(p, m)` reports its real `(skips, ops, msg_elems)`:
//!
//! * **Round regime** (small m): full-vector messages, `msg_elems = m`.
//!   `T ≈ q·α + c` — the α term dominates, so the round-optimal
//!   123-doubling (q = ⌈log₂(p−1) + log₂(4/3)⌉) wins.
//! * **Bandwidth regime** (large m): decomposed messages. The β term is
//!   `rounds · (msg_elems · elem_bytes) · β`, i.e. `F · m · elem_bytes ·
//!   β` with the **bandwidth factor** `F = rounds · msg_elems / m`:
//!   123-doubling F = q; pipelined chain F = 1 + (p−2)/B (B ≤ 64);
//!   block decomposition F = 2 − 2/g + q(p/g)/g; reduce-scatter +
//!   allgather F = 2 − 2/p. The crossover m between any two schedules is
//!   where `ΔF · m · elem_bytes · β = Δrounds · α + Δ(ops·bytes) · γ`;
//!   [`crossover_m`] solves it numerically against the actual (possibly
//!   m-dependent) schedules and the selection sweep in
//!   `benches/hotpath.rs` gates that [`crate::coll::select_exscan`]
//!   lands on the argmin at every sweep point.

use super::model::{CostParams, LinkClass};
use crate::coll::{two_level_ops, two_level_rounds};
use crate::topo::Topo;
use crate::util::bits::rounds_123;
use crate::util::ceil_log2;

/// Closed-form prediction summary for one (algorithm, p, m) point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlatPrediction {
    pub rounds: u32,
    pub intra_rounds: u32,
    pub inter_rounds: u32,
    pub ops: u32,
    pub time_us: f64,
}

/// Classify one critical-path round by the skip distance under block
/// placement: the critical rank is `p−1`; its partner is `p−1−s`.
pub fn skip_link(p: usize, ranks_per_node: usize, skip: usize) -> LinkClass {
    let r = p - 1;
    let partner = r.saturating_sub(skip);
    if r / ranks_per_node == partner / ranks_per_node {
        LinkClass::IntraNode
    } else {
        LinkClass::InterNode
    }
}

/// Closed-form predicted completion time.
///
/// * `skips` — the algorithm's critical-path receive distances
///   ([`crate::coll::ScanAlgorithm::critical_skips`]).
/// * `ops` — ⊕ applications on the critical path
///   ([`crate::coll::ScanAlgorithm::predicted_ops`]).
pub fn predict_flat(
    skips: &[usize],
    ops: u32,
    p: usize,
    ranks_per_node: usize,
    bytes: usize,
    params: &CostParams,
) -> FlatPrediction {
    let mut time = params.overhead;
    let mut intra = 0u32;
    let mut inter = 0u32;
    for &s in skips {
        let link = skip_link(p.max(2), ranks_per_node, s);
        match link {
            LinkClass::IntraNode => intra += 1,
            LinkClass::InterNode => inter += 1,
            LinkClass::SelfLoop => {}
        }
        time += params.alpha(link) + bytes as f64 * params.beta(link);
    }
    time += ops as f64 * bytes as f64 * params.gamma;
    FlatPrediction { rounds: skips.len() as u32, intra_rounds: intra, inter_rounds: inter, ops, time_us: time }
}

/// Price one `(skips, ops, msg_elems)` schedule — the triple
/// [`crate::coll::ScanAlgorithm::critical_schedule`] reports — at a
/// concrete element width.
pub fn predict_schedule(
    schedule: &(Vec<usize>, u32, usize),
    p: usize,
    ranks_per_node: usize,
    elem_bytes: usize,
    params: &CostParams,
) -> FlatPrediction {
    let (skips, ops, msg_elems) = schedule;
    predict_flat(skips, *ops, p, ranks_per_node, msg_elems * elem_bytes, params)
}

/// [`predict_flat`] against a concrete [`Topo`] link matrix instead of
/// class parameters: each critical-path round is priced on the actual
/// `(p−1−s) → (p−1)` link, so jitter and hierarchy show up in the
/// ranking exactly as the virtual clock will charge them. γ and the
/// overhead come from the topology's machine-wide base parameters.
pub fn predict_flat_topo(skips: &[usize], ops: u32, bytes: usize, topo: &Topo) -> FlatPrediction {
    let p = topo.size();
    let r = p.saturating_sub(1);
    let mut time = topo.overhead();
    let mut intra = 0u32;
    let mut inter = 0u32;
    for &s in skips {
        let partner = r.saturating_sub(s);
        match topo.link(partner, r) {
            LinkClass::IntraNode => intra += 1,
            LinkClass::InterNode => inter += 1,
            LinkClass::SelfLoop => {}
        }
        time += topo.hop_cost(partner, r, bytes);
    }
    time += ops as f64 * bytes as f64 * topo.gamma();
    FlatPrediction { rounds: skips.len() as u32, intra_rounds: intra, inter_rounds: inter, ops, time_us: time }
}

/// Phase-composed prediction of [`ExscanTwoLevel`] on this topology:
/// the completion chain runs through the last group's intra-node exscan,
/// its totals hop, the leader exscan across groups (the only inter-node
/// hops), and the binomial broadcast plus final fold back down —
/// each hop priced on its actual link. `bytes` is the full per-message
/// payload (the scheme never decomposes the vector).
///
/// [`ExscanTwoLevel`]: crate::coll::ExscanTwoLevel
pub fn predict_two_level(topo: &Topo, bytes: usize) -> FlatPrediction {
    let p = topo.size();
    let ppn = topo.ranks_per_node();
    let g = topo.nodes();
    let ops = two_level_ops(ppn, p);
    let rounds = two_level_rounds(ppn, p);
    if p <= 1 {
        return FlatPrediction { rounds, intra_rounds: 0, inter_rounds: 0, ops, time_us: topo.overhead() };
    }
    let mut time = topo.overhead();
    let mut intra = 0u32;
    let mut inter = 0u32;
    let mut hop = |from: usize, to: usize| -> f64 {
        match topo.link(from, to) {
            LinkClass::IntraNode => intra += 1,
            LinkClass::InterNode => inter += 1,
            LinkClass::SelfLoop => {}
        }
        topo.hop_cost(from, to, bytes)
    };
    let lo = (g - 1) * ppn; // leader of the last (here: full) group
    let kl = p - lo;
    let gamma_term = bytes as f64 * topo.gamma();
    // Phase 1: intra-node 123 on the last group, completion at its last
    // member (q−1 folds); phase 2: that member's total prep (one γ) +
    // hop to the leader.
    if kl > 1 {
        let last = p - 1;
        for k in 0..rounds_123(kl) {
            let s = match k {
                0 => 1,
                1 => 2,
                _ => 3 * (1usize << (k - 2)),
            };
            time += hop(last - s.min(kl - 1), last);
        }
        time += rounds_123(kl).saturating_sub(1) as f64 * gamma_term;
        time += hop(last, lo) + gamma_term;
    }
    // Phase 3: leader 123 across groups — completion at the last leader
    // (its folds serialize on the chain even though they land on a
    // different rank than the phase-1 ones).
    if g > 1 {
        for k in 0..rounds_123(g) {
            let s = match k {
                0 => 1,
                1 => 2,
                _ => 3 * (1usize << (k - 2)),
            };
            time += hop((g - 1 - s.min(g - 1)) * ppn, lo);
        }
        time += rounds_123(g).saturating_sub(1) as f64 * gamma_term;
    }
    // Phase 4: binomial broadcast back down the last group + final fold.
    if g > 1 && kl > 1 {
        for i in 0..ceil_log2(kl) {
            time += hop(lo, lo + (1usize << i).min(kl - 1));
        }
        time += gamma_term;
    }
    FlatPrediction { rounds, intra_rounds: intra, inter_rounds: inter, ops, time_us: time }
}

/// Smallest vector length `m ∈ [1, m_max]` at which schedule `b` prices
/// strictly below schedule `a`, or `None` if `a` wins everywhere in the
/// range. Both schedules are functions of m (group widths and block
/// counts may change along the sweep), so this scans doubling m — exact
/// enough for regime boundaries, which the tuning table buckets by
/// powers of two anyway.
pub fn crossover_m(
    schedule_a: impl Fn(usize) -> (Vec<usize>, u32, usize),
    schedule_b: impl Fn(usize) -> (Vec<usize>, u32, usize),
    p: usize,
    ranks_per_node: usize,
    elem_bytes: usize,
    params: &CostParams,
    m_max: usize,
) -> Option<usize> {
    let mut m = 1usize;
    while m <= m_max {
        let ta = predict_schedule(&schedule_a(m), p, ranks_per_node, elem_bytes, params);
        let tb = predict_schedule(&schedule_b(m), p, ranks_per_node, elem_bytes, params);
        if tb.time_us < ta.time_us {
            return Some(m);
        }
        m = m.saturating_mul(2);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostParams;

    #[test]
    fn skip_link_block_placement() {
        // p = 1152, 32 ranks/node: rank 1151's partner at distance 16 is
        // 1135 — same node (both / 32 == 35). Distance 32 crosses.
        assert_eq!(skip_link(1152, 32, 16), LinkClass::IntraNode);
        assert_eq!(skip_link(1152, 32, 31), LinkClass::IntraNode);
        assert_eq!(skip_link(1152, 32, 32), LinkClass::InterNode);
        // One rank per node: everything crosses.
        assert_eq!(skip_link(36, 1, 1), LinkClass::InterNode);
    }

    #[test]
    fn prediction_composes() {
        let params = CostParams {
            alpha_intra: 1.0,
            alpha_inter: 10.0,
            beta_intra: 0.0,
            beta_inter: 0.1,
            gamma: 0.01,
            overhead: 5.0,
        };
        // Two inter rounds + one intra round at 100 bytes, 2 ops.
        let pred = predict_flat(&[32, 64, 1], 2, 128, 32, 100, &params);
        assert_eq!(pred.inter_rounds, 2);
        assert_eq!(pred.intra_rounds, 1);
        // 5 + 2*(10+10) + 1*1 + 2*100*0.01 = 5+40+1+2 = 48
        assert!((pred.time_us - 48.0).abs() < 1e-9);
    }

    #[test]
    fn crossover_finds_bandwidth_regime_boundary() {
        // Round-regime schedule: 6 full-vector rounds (123 at p = 36).
        // Bandwidth-regime schedule: 70 rounds of m/36 elements (rsag).
        // Tiny m → a wins (fewer α); large m → b wins (F ≈ 1.94 < 6).
        let params = CostParams::generic();
        let a = |m: usize| (vec![1usize; 6], 5u32, m);
        let b = |m: usize| (vec![1usize; 70], 34u32, m.div_ceil(36));
        let m_star = crossover_m(a, b, 36, 1, 8, &params, 1 << 24).expect("must cross");
        assert!(m_star > 1, "a must win at m = 1");
        // On either side of the boundary the ordering flips.
        let ta = predict_schedule(&a(m_star), 36, 1, 8, &params);
        let tb = predict_schedule(&b(m_star), 36, 1, 8, &params);
        assert!(tb.time_us < ta.time_us);
        let ta1 = predict_schedule(&a(1), 36, 1, 8, &params);
        let tb1 = predict_schedule(&b(1), 36, 1, 8, &params);
        assert!(ta1.time_us < tb1.time_us);
    }

    #[test]
    fn more_rounds_costs_more() {
        let params = CostParams::generic();
        let a = predict_flat(&[1, 2, 4, 8, 16, 32], 5, 36, 1, 80, &params);
        let b = predict_flat(&[1, 1, 2, 4, 8, 16, 32], 6, 36, 1, 80, &params);
        assert!(b.time_us > a.time_us);
    }
}
