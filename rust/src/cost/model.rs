//! Cost parameters and the link-classified round-cost function.

use std::sync::Arc;

use crate::topo::Topo;

/// Class of the link between two ranks, given a hierarchical placement.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// Message to self (allowed by MPI; copies through memory).
    SelfLoop,
    /// Both ranks on the same compute node (shared memory transport).
    IntraNode,
    /// Ranks on different compute nodes (network transport).
    InterNode,
}

/// Parameters of the hierarchical α-β-γ model. Units: microseconds and
/// microseconds/byte, matching the paper's reporting unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostParams {
    /// Per-message latency within a node (µs).
    pub alpha_intra: f64,
    /// Per-message latency across nodes (µs).
    pub alpha_inter: f64,
    /// Inverse bandwidth within a node (µs/byte).
    pub beta_intra: f64,
    /// Inverse bandwidth across nodes (µs/byte).
    pub beta_inter: f64,
    /// Local reduction (⊕ application) cost (µs/byte).
    pub gamma: f64,
    /// Fixed per-collective-call overhead (µs): library entry, argument
    /// checking, buffer setup.
    pub overhead: f64,
}

impl CostParams {
    /// Parameters fitted to the paper's Table 1, p = 36×1 configuration
    /// (one rank per node: every link is inter-node Omnipath). Computed
    /// once by the non-negative least-squares fit in [`super::calibrate`]
    /// over the embedded paper data — `exscan calibrate` prints the values.
    pub fn paper_36x1() -> Self {
        static C: std::sync::OnceLock<CostParams> = std::sync::OnceLock::new();
        *C.get_or_init(|| super::calibrate::fit_flat(&super::calibrate::PAPER_TABLE1_36X1, 8).params)
    }

    /// Effective parameters of the *native* MPI_Exscan in the 36×1
    /// configuration (same fit, native column).
    pub fn paper_36x1_native() -> Self {
        static C: std::sync::OnceLock<CostParams> = std::sync::OnceLock::new();
        *C.get_or_init(|| {
            super::calibrate::fit_flat(&super::calibrate::PAPER_TABLE1_36X1, 8).native_params
        })
    }

    /// Parameters fitted to the paper's Table 1, p = 36×32 configuration
    /// (32 ranks per node, block placement).
    pub fn paper_36x32() -> Self {
        static C: std::sync::OnceLock<CostParams> = std::sync::OnceLock::new();
        *C.get_or_init(|| super::calibrate::fit_flat(&super::calibrate::PAPER_TABLE1_36X32, 8).params)
    }

    /// Native-column fit for the 36×32 configuration.
    pub fn paper_36x32_native() -> Self {
        static C: std::sync::OnceLock<CostParams> = std::sync::OnceLock::new();
        *C.get_or_init(|| {
            super::calibrate::fit_flat(&super::calibrate::PAPER_TABLE1_36X32, 8).native_params
        })
    }

    /// A generic small-cluster preset for examples (not calibrated).
    pub fn generic() -> Self {
        CostParams {
            alpha_intra: 0.5,
            alpha_inter: 1.5,
            beta_intra: 5e-5,
            beta_inter: 2.5e-4,
            gamma: 1e-4,
            overhead: 1.0,
        }
    }

    pub fn alpha(&self, link: LinkClass) -> f64 {
        match link {
            LinkClass::SelfLoop => 0.0,
            LinkClass::IntraNode => self.alpha_intra,
            LinkClass::InterNode => self.alpha_inter,
        }
    }

    pub fn beta(&self, link: LinkClass) -> f64 {
        match link {
            LinkClass::SelfLoop => 0.0,
            LinkClass::IntraNode => self.beta_intra,
            LinkClass::InterNode => self.beta_inter,
        }
    }
}

/// The evaluated cost model: parameters + placement geometry, with an
/// optional per-link [`Topo`] matrix overriding the class parameters.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub params: CostParams,
    /// Ranks per node under block placement (`node = rank / ranks_per_node`).
    pub ranks_per_node: usize,
    /// When set, `round_cost` prices each hop off the per-link matrix
    /// instead of the class parameters (which then only carry γ and the
    /// overhead). Accounting passes world ranks, so the matrix applies
    /// transparently inside sub-communicators too.
    pub topo: Option<Arc<Topo>>,
}

impl CostModel {
    pub fn new(params: CostParams, ranks_per_node: usize) -> Self {
        assert!(ranks_per_node >= 1);
        CostModel { params, ranks_per_node, topo: None }
    }

    /// A model priced entirely off a topology's per-link matrix. The
    /// class parameters are the topology's base values (so γ, overhead,
    /// and the closed-form predictors stay consistent with the matrix).
    pub fn with_topo(topo: Arc<Topo>) -> Self {
        CostModel {
            params: topo.class_params(),
            ranks_per_node: topo.ranks_per_node(),
            topo: Some(topo),
        }
    }

    /// Classify the link between two ranks under block placement.
    pub fn link(&self, a: usize, b: usize) -> LinkClass {
        if a == b {
            LinkClass::SelfLoop
        } else if a / self.ranks_per_node == b / self.ranks_per_node {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }

    /// Time (µs) for one communication round transferring `bytes` bytes
    /// between `from` and `to` (one simultaneous send-receive slot).
    pub fn round_cost(&self, from: usize, to: usize, bytes: usize) -> f64 {
        if let Some(topo) = &self.topo {
            return topo.hop_cost(from, to, bytes);
        }
        let l = self.link(from, to);
        self.params.alpha(l) + bytes as f64 * self.params.beta(l)
    }

    /// Time (µs) for one ⊕ application (`MPI_Reduce_local`) over `bytes`.
    pub fn reduce_cost(&self, bytes: usize) -> f64 {
        bytes as f64 * self.params.gamma
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_classification_block_placement() {
        let m = CostModel::new(CostParams::generic(), 32);
        assert_eq!(m.link(0, 0), LinkClass::SelfLoop);
        assert_eq!(m.link(0, 31), LinkClass::IntraNode);
        assert_eq!(m.link(31, 32), LinkClass::InterNode);
        assert_eq!(m.link(64, 95), LinkClass::IntraNode);
        assert_eq!(m.link(0, 1151), LinkClass::InterNode);
    }

    #[test]
    fn one_rank_per_node_is_all_inter() {
        let m = CostModel::new(CostParams::generic(), 1);
        for a in 0..8 {
            for b in 0..8 {
                if a != b {
                    assert_eq!(m.link(a, b), LinkClass::InterNode);
                }
            }
        }
    }

    #[test]
    fn round_cost_monotone_in_bytes() {
        let m = CostModel::new(CostParams::generic(), 4);
        assert!(m.round_cost(0, 5, 800) > m.round_cost(0, 5, 8));
        assert!(m.round_cost(0, 1, 800) < m.round_cost(0, 5, 800));
    }

    #[test]
    fn self_loop_free() {
        let m = CostModel::new(CostParams::generic(), 4);
        assert_eq!(m.round_cost(3, 3, 1 << 20), 0.0);
    }

    #[test]
    fn topo_matrix_overrides_class_params() {
        let topo = Arc::new(crate::topo::Topo::two_level(2, 3, 9));
        let m = CostModel::with_topo(topo.clone());
        assert_eq!(m.ranks_per_node, 3);
        // Every hop prices off the matrix exactly…
        for from in 0..6 {
            for to in 0..6 {
                assert_eq!(m.round_cost(from, to, 64), topo.hop_cost(from, to, 64));
            }
        }
        // …so intra hops are cheap, inter hops expensive, self-loops free.
        assert!(m.round_cost(0, 1, 8) < m.round_cost(0, 3, 8));
        assert_eq!(m.round_cost(2, 2, 1 << 20), 0.0);
        // γ and overhead carry over from the topology's base parameters.
        assert_eq!(m.params.gamma, topo.gamma());
        assert_eq!(m.params.overhead, topo.overhead());
    }

    #[test]
    fn presets_nonnegative() {
        for p in [
            CostParams::paper_36x1(),
            CostParams::paper_36x1_native(),
            CostParams::paper_36x32(),
            CostParams::paper_36x32_native(),
            CostParams::generic(),
        ] {
            assert!(p.alpha_intra >= 0.0 && p.alpha_inter >= 0.0);
            assert!(p.beta_intra >= 0.0 && p.beta_inter >= 0.0);
            assert!(p.gamma >= 0.0 && p.overhead >= 0.0);
            // Some β term must be positive: large vectors cost time.
            assert!(p.beta_inter + p.beta_intra > 0.0);
        }
    }
}
