//! Calibration: fit the α-β-γ parameters to the paper's Table 1 by
//! non-negative linear least squares.
//!
//! The model is *linear in the parameters* once the round/op counts are
//! fixed: every measurement `(algorithm, m)` contributes one row
//!
//! ```text
//!   t = n_intra·α_intra + n_inter·α_inter
//!     + bytes·n_intra·β_intra + bytes·n_inter·β_inter
//!     + n_ops·bytes·γ + c
//! ```
//!
//! with the counts taken from the algorithms' closed forms (Section 2 of
//! the paper / the `coll` implementations — cross-checked against traces
//! in the integration tests). We fit the three portable algorithms
//! jointly (shared parameters), then fit the *native* MPI_Exscan column
//! separately with γ pinned: the native implementation runs the same
//! recursive-doubling pattern but pays the library's internal copy and
//! protocol costs, which surface as larger effective α/β — exactly the
//! gap the paper attributes to "possible and worthwhile improvements".


use super::model::{CostParams, LinkClass};
use super::predict::skip_link;
use crate::util::linalg::nnls;
use crate::util::{ceil_log2, bits::rounds_123};

/// One configuration's worth of Table 1 (times in µs per element count).
#[derive(Debug, Clone)]
pub struct Table1Data {
    pub label: &'static str,
    pub p: usize,
    pub ranks_per_node: usize,
    /// Element counts (MPI_LONG = 8 bytes each).
    pub m: &'static [usize],
    pub native: &'static [f64],
    pub two_op: &'static [f64],
    pub one_doubling: &'static [f64],
    pub otd123: &'static [f64],
}

/// Table 1, p = 36×1 (one MPI process per node).
pub const PAPER_TABLE1_36X1: Table1Data = Table1Data {
    label: "36x1",
    p: 36,
    ranks_per_node: 1,
    m: &[1, 10, 100, 1000, 10_000, 100_000],
    native: &[10.61, 16.86, 18.78, 36.77, 276.31, 2558.52],
    two_op: &[8.92, 15.68, 17.34, 34.98, 247.39, 1789.40],
    one_doubling: &[9.79, 18.29, 19.83, 35.13, 218.06, 1351.72],
    otd123: &[9.17, 16.58, 17.95, 32.38, 207.29, 1333.91],
};

/// Table 1, p = 36×32 = 1152 (fully populated nodes).
pub const PAPER_TABLE1_36X32: Table1Data = Table1Data {
    label: "36x32",
    p: 1152,
    ranks_per_node: 32,
    m: &[1, 10, 100, 1000, 10_000, 100_000],
    native: &[27.27, 31.59, 37.55, 160.34, 1124.82, 14456.12],
    two_op: &[22.23, 33.55, 38.77, 160.40, 1103.67, 15107.82],
    one_doubling: &[25.61, 36.36, 40.96, 155.99, 1095.03, 11120.00],
    otd123: &[25.36, 35.67, 39.97, 147.20, 1018.43, 10921.26],
};

/// Critical-path receive skips of the three portable algorithms and the
/// native baseline (kept local to avoid a layering cycle; the integration
/// suite asserts these equal `ScanAlgorithm::critical_skips`).
pub fn skips_two_op(p: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut s = 1;
    while s < p {
        out.push(s);
        s *= 2;
    }
    out
}

pub fn skips_one_doubling(p: usize) -> Vec<usize> {
    let mut out = vec![1];
    let mut s = 1;
    while s < p.saturating_sub(1) {
        out.push(s);
        s *= 2;
    }
    out
}

pub fn skips_123(p: usize) -> Vec<usize> {
    (0..rounds_123(p))
        .map(|k| match k {
            0 => 1,
            1 => 2,
            _ => 3 * (1usize << (k - 2)),
        })
        .collect()
}

pub fn skips_native(p: usize) -> Vec<usize> {
    skips_two_op(p)
}

/// Paper-counted ⊕ applications (see the algorithm docs).
pub fn ops_two_op(p: usize) -> u32 {
    if p <= 1 { 0 } else { 2 * ceil_log2(p) - 1 }
}

pub fn ops_one_doubling(p: usize) -> u32 {
    if p <= 2 { 0 } else { ceil_log2(p - 1) }
}

pub fn ops_123(p: usize) -> u32 {
    rounds_123(p).saturating_sub(1)
}

pub fn ops_native(p: usize) -> u32 {
    ops_two_op(p)
}

/// Result of one calibration fit.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    pub label: String,
    /// Shared parameters of the three portable algorithms.
    pub params: CostParams,
    /// Effective parameters of the native MPI_Exscan (γ shared).
    pub native_params: CostParams,
    /// Root-mean-square relative error over the fitted points.
    pub rel_rmse: f64,
    pub native_rel_rmse: f64,
}

fn design_row(
    p: usize,
    rpn: usize,
    skips: &[usize],
    ops: u32,
    bytes: usize,
) -> Vec<f64> {
    let mut n_intra = 0.0;
    let mut n_inter = 0.0;
    for &s in skips {
        match skip_link(p, rpn, s) {
            LinkClass::IntraNode => n_intra += 1.0,
            LinkClass::InterNode => n_inter += 1.0,
            LinkClass::SelfLoop => {}
        }
    }
    let b = bytes as f64;
    vec![n_intra, n_inter, b * n_intra, b * n_inter, ops as f64 * b, 1.0]
}

fn predict_row(row: &[f64], x: &[f64]) -> f64 {
    row.iter().zip(x).map(|(a, b)| a * b).sum()
}

fn rel_rmse(rows: &[Vec<f64>], targets: &[f64], x: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (row, &t) in rows.iter().zip(targets) {
        let e = (predict_row(row, x) - t) / t;
        acc += e * e;
    }
    (acc / targets.len() as f64).sqrt()
}

/// Fit shared parameters to one configuration of Table 1.
///
/// `bytes_per_elem` is 8 for the paper's MPI_LONG.
pub fn fit_flat(data: &Table1Data, bytes_per_elem: usize) -> CalibrationReport {
    let (p, rpn) = (data.p, data.ranks_per_node);
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut targets: Vec<f64> = Vec::new();
    let algos: [(&[f64], Vec<usize>, u32); 3] = [
        (data.two_op, skips_two_op(p), ops_two_op(p)),
        (data.one_doubling, skips_one_doubling(p), ops_one_doubling(p)),
        (data.otd123, skips_123(p), ops_123(p)),
    ];
    for (times, skips, ops) in &algos {
        for (&m, &t) in data.m.iter().zip(times.iter()) {
            rows.push(design_row(p, rpn, skips, *ops, m * bytes_per_elem));
            targets.push(t);
        }
    }
    // Relative weighting: scale each equation by 1/t so the fit minimizes
    // *relative* error — otherwise the m = 100 000 rows (milliseconds)
    // drown the m = 1 rows (microseconds) and the α/overhead terms vanish.
    let wrows: Vec<Vec<f64>> = rows
        .iter()
        .zip(&targets)
        .map(|(r, &t)| r.iter().map(|v| v / t).collect())
        .collect();
    let wtargets: Vec<f64> = vec![1.0; targets.len()];
    let x = nnls(&wrows, &wtargets).expect("calibration fit is well-posed");
    let params = CostParams {
        alpha_intra: x[0],
        alpha_inter: x[1],
        beta_intra: x[2],
        beta_inter: x[3],
        gamma: x[4],
        overhead: x[5],
    };
    let fit_err = rel_rmse(&rows, &targets, &x);

    // Native column: a single algorithm cannot separate α from the call
    // overhead (both constant across m) nor intra from inter (both round
    // counts are m-independent), so the native fit is the 2-parameter
    // affine model  t = A + B·bytes  (γ and overhead pinned from the
    // portable fit), with A distributed over α_intra/α_inter and B over
    // β_intra/β_inter in the portable parameters' ratios.
    let nskips = skips_native(p);
    let nops = ops_native(p);
    let proto = design_row(p, rpn, &nskips, nops, 1); // per-byte counts
    let (n_intra, n_inter) = (proto[0], proto[1]);
    let mut nrows: Vec<Vec<f64>> = Vec::new();
    let mut ntargets: Vec<f64> = Vec::new();
    for (&m, &t) in data.m.iter().zip(data.native.iter()) {
        let bytes = (m * bytes_per_elem) as f64;
        nrows.push(vec![1.0, bytes]);
        ntargets.push(t - params.overhead - nops as f64 * bytes * params.gamma);
    }
    let wnrows: Vec<Vec<f64>> = nrows
        .iter()
        .zip(data.native.iter())
        .map(|(r, &t)| r.iter().map(|v| v / t).collect())
        .collect();
    let wntargets: Vec<f64> = ntargets
        .iter()
        .zip(data.native.iter())
        .map(|(&adj, &t)| adj / t)
        .collect();
    let nx = nnls(&wnrows, &wntargets).expect("native affine fit is well-posed");
    let (a_total, b_total) = (nx[0], nx[1]);
    // Distribute A over the α's and B over the β's, keeping the portable
    // intra:inter ratios (falling back to all-inter when degenerate).
    let ratio = |intra: f64, inter: f64| if inter > 1e-12 { intra / inter } else { 0.0 };
    let rho_a = ratio(params.alpha_intra, params.alpha_inter);
    let rho_b = ratio(params.beta_intra, params.beta_inter);
    let denom_a = n_inter + n_intra * rho_a;
    let denom_b = n_inter + n_intra * rho_b;
    let alpha_inter_n = if denom_a > 0.0 { a_total / denom_a } else { 0.0 };
    let beta_inter_n = if denom_b > 0.0 { b_total / denom_b } else { 0.0 };
    let native_params = CostParams {
        alpha_intra: alpha_inter_n * rho_a,
        alpha_inter: alpha_inter_n,
        beta_intra: beta_inter_n * rho_b,
        beta_inter: beta_inter_n,
        gamma: params.gamma,
        overhead: params.overhead,
    };
    // Recompute native error against the raw targets.
    let mut acc = 0.0;
    for ((row, &t0), &m) in nrows.iter().zip(data.native.iter()).zip(data.m.iter()) {
        let pred = predict_row(row, &nx)
            + params.overhead
            + (m * bytes_per_elem) as f64 * nops as f64 * params.gamma;
        let e = (pred - t0) / t0;
        acc += e * e;
    }
    CalibrationReport {
        label: data.label.to_string(),
        params,
        native_params,
        rel_rmse: fit_err,
        native_rel_rmse: (acc / data.native.len() as f64).sqrt(),
    }
}

/// Fit separate intra-node vs inter-node α-β class parameters from a
/// [`Topo`] per-link matrix: classify every directed link by the
/// topology's block placement and take the class means (the least-squares
/// estimate under the generative model `link = class_base · jitter`,
/// since the jitter is mean-one). γ and the overhead are machine-wide,
/// not per-link, and carry over from the topology. This is what the
/// topology-aware selection uses when it needs class parameters back out
/// of a measured (or synthesized) matrix.
///
/// [`Topo`]: crate::topo::Topo
pub fn fit_topo(topo: &crate::topo::Topo) -> CostParams {
    let p = topo.size();
    let (mut a_intra, mut b_intra, mut n_intra) = (0.0f64, 0.0f64, 0usize);
    let (mut a_inter, mut b_inter, mut n_inter) = (0.0f64, 0.0f64, 0usize);
    for from in 0..p {
        for to in 0..p {
            match topo.link(from, to) {
                LinkClass::SelfLoop => {}
                LinkClass::IntraNode => {
                    a_intra += topo.alpha(from, to);
                    b_intra += topo.beta(from, to);
                    n_intra += 1;
                }
                LinkClass::InterNode => {
                    a_inter += topo.alpha(from, to);
                    b_inter += topo.beta(from, to);
                    n_inter += 1;
                }
            }
        }
    }
    let mean = |sum: f64, n: usize| if n > 0 { sum / n as f64 } else { 0.0 };
    CostParams {
        alpha_intra: mean(a_intra, n_intra),
        alpha_inter: mean(a_inter, n_inter),
        beta_intra: mean(b_intra, n_intra),
        beta_inter: mean(b_inter, n_inter),
        gamma: topo.gamma(),
        overhead: topo.overhead(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_skip_counts() {
        assert_eq!(skips_two_op(36).len(), 6);
        assert_eq!(skips_one_doubling(36).len(), 7);
        assert_eq!(skips_123(36).len(), 6);
        assert_eq!(skips_123(36), vec![1, 2, 3, 6, 12, 24]);
        assert_eq!(skips_two_op(1152).len(), 11);
        assert_eq!(skips_one_doubling(1152).len(), 12);
        assert_eq!(skips_123(1152).len(), 11);
    }

    #[test]
    fn fit_36x1_reasonable() {
        let rep = fit_flat(&PAPER_TABLE1_36X1, 8);
        // All parameters non-negative (nnls) and the fit tracks the data
        // to within ~35% relative RMSE (the paper's min-of-max measurements
        // include effects outside any linear model).
        assert!(rep.params.alpha_inter >= 0.0);
        assert!(rep.params.gamma >= 0.0);
        assert!(rep.rel_rmse < 0.35, "rel_rmse = {}", rep.rel_rmse);
        // Native must come out at least as expensive per round as portable.
        assert!(
            rep.native_params.alpha_inter + rep.native_params.overhead
                >= 0.5 * (rep.params.alpha_inter + rep.params.overhead)
        );
    }

    #[test]
    fn fit_36x32_reasonable() {
        let rep = fit_flat(&PAPER_TABLE1_36X32, 8);
        assert!(rep.rel_rmse < 0.5, "rel_rmse = {}", rep.rel_rmse);
        assert!(rep.params.beta_inter >= 0.0);
    }

    #[test]
    fn fit_topo_recovers_class_means() {
        // Per-link jitter is mean-one and bounded, so the class means of
        // a synthesized matrix must land within the jitter band of the
        // preset bases — and far tighter in practice (many links).
        let topo = crate::topo::Topo::two_level(4, 9, 77);
        let base = topo.class_params();
        let fit = fit_topo(&topo);
        let close = |got: f64, want: f64| (got - want).abs() <= 0.05 * want + 1e-12;
        assert!(close(fit.alpha_intra, base.alpha_intra), "α_intra {}", fit.alpha_intra);
        assert!(close(fit.alpha_inter, base.alpha_inter), "α_inter {}", fit.alpha_inter);
        assert!(close(fit.beta_intra, base.beta_intra), "β_intra {}", fit.beta_intra);
        assert!(close(fit.beta_inter, base.beta_inter), "β_inter {}", fit.beta_inter);
        assert_eq!(fit.gamma, base.gamma);
        assert_eq!(fit.overhead, base.overhead);
        // And the recovered classes actually separate on a hierarchy…
        assert!(fit.alpha_inter > 10.0 * fit.alpha_intra);
        // …but coincide (within jitter) on the uniform preset.
        let flat = fit_topo(&crate::topo::Topo::flat(16, 77));
        assert!(close(flat.alpha_intra, flat.alpha_inter) || flat.alpha_intra == 0.0);
    }

    #[test]
    fn fitted_model_preserves_ordering_at_large_m() {
        // The model must reproduce the paper's headline shape: at
        // m = 100000, 123-doubling <= 1-doubling <= two-op (36x1).
        let rep = fit_flat(&PAPER_TABLE1_36X1, 8);
        let p = 36;
        let bytes = 100_000 * 8;
        let t = |skips: &[usize], ops: u32| {
            super::super::predict::predict_flat(skips, ops, p, 1, bytes, &rep.params).time_us
        };
        let t123 = t(&skips_123(p), ops_123(p));
        let t1d = t(&skips_one_doubling(p), ops_one_doubling(p));
        let t2op = t(&skips_two_op(p), ops_two_op(p));
        assert!(t123 <= t1d + 1e-9, "123 {t123} vs 1-dbl {t1d}");
        assert!(t1d <= t2op + 1e-9, "1-dbl {t1d} vs two-op {t2op}");
    }
}
