//! The α-β-γ communication/computation cost model.
//!
//! The paper's analysis counts two machine-independent quantities per
//! algorithm: *communication rounds* (each a simultaneous send-receive of an
//! m-element vector) and *applications of ⊕* (each an `MPI_Reduce_local`
//! over m elements). The classic linear (Hockney / LogGP-flavoured) model
//! turns these into time:
//!
//! ```text
//!   T  =  Σ_rounds (α_link + bytes · β_link)  +  Σ_ops bytes · γ  +  c
//! ```
//!
//! with `α` the per-message latency of the link class used in that round,
//! `β` the inverse bandwidth (µs/byte), `γ` the local reduction cost
//! (µs/byte) and `c` a fixed per-call overhead. Links are classified
//! hierarchically (same rank / same node / across nodes), which is what
//! makes the 36×32 configuration behave differently from 36×1 in the paper.
//!
//! [`calibrate`] fits the parameters to the paper's Table 1 by non-negative
//! linear least squares; [`predict`] produces closed-form and trace-replay
//! predictions used for algorithm selection and for the model-vs-measured
//! experiment.
//!
//! The model prices **per-message** bytes, so it covers both of the
//! paper's regimes with one formula: small m (full-vector messages —
//! round count decides) and large m (block-decomposed `m/g`- or
//! `m/p`-element messages — the bandwidth factor decides). See the
//! regime derivation in [`predict`] and the [`predict::crossover_m`]
//! boundary solver that the large-m selection gates build on.

pub mod calibrate;
pub mod model;
pub mod predict;

pub use calibrate::{
    fit_flat, fit_topo, CalibrationReport, Table1Data, PAPER_TABLE1_36X1, PAPER_TABLE1_36X32,
};
pub use model::{CostModel, CostParams, LinkClass};
pub use predict::{
    crossover_m, predict_flat, predict_flat_topo, predict_schedule, predict_two_level, skip_link,
    FlatPrediction,
};
