//! The benchmarking subsystem: an mpicroscope-style measurement harness
//! (the procedure the paper's Section 3 describes), workload generators,
//! table/CSV formatting, and the runners that regenerate the paper's
//! Table 1 and Figure 1.

pub mod experiments;
pub mod harness;
pub mod table;
pub mod workload;

pub use experiments::{figure1_sweep, table1_rows, ExperimentRow, PaperConfig};
pub use harness::{measure_exscan, measure_exscan_world, BenchConfig, Harness, Measurement};
pub use table::{
    format_table, hotpath_json, to_csv, CrossoverPoint, HotpathPoint, KernelPoint, LatencyPoint,
    MSweepPoint, SoakPoint, SvcLatencyPoint, SvcPoint, TopoSweepPoint, WireFaultPoint,
};
pub use workload::{inputs_i64, inputs_rec2, inputs_seg_i64, SweepSpec};
