//! The measurement harness, reproducing the paper's benchmarking procedure
//! (Section 3, after [Träff, mpicroscope]):
//!
//! * per element count: `warmups` warm-up executions, then `reps` measured
//!   repetitions;
//! * processes synchronized with a barrier (twice) before each repetition;
//! * per repetition the time of the **slowest** rank is taken;
//! * over repetitions the **minimum** of those maxima is reported.
//!
//! All measurement flows through one persistent [`World`] executor: rank
//! threads are spawned once per sweep (not once per (algorithm, m) point)
//! and repetition cost is pure algorithm execution, as in MPI. Transport
//! buffer pools stay warm across points, so steady-state rounds never
//! touch the allocator (EXPERIMENTS.md §Perf).

use anyhow::Result;

use crate::coll::ScanAlgorithm;
use crate::mpi::ctx::ClockMode;
use crate::mpi::{Elem, OpRef, World, WorldConfig};
use crate::util::Summary;

/// Repetition policy. `Default` matches the paper: 15 warmups, 200 reps.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmups: usize,
    pub reps: usize,
    /// Verify the first repetition's output against the sequential oracle.
    pub validate: bool,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmups: 15, reps: 200, validate: true }
    }
}

impl BenchConfig {
    /// A fast policy for CI / smoke runs.
    pub fn quick() -> Self {
        BenchConfig { warmups: 2, reps: 20, validate: true }
    }
}

/// One measured (algorithm, m) point.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub algo: String,
    /// Operator name, recorded once per measurement point (the per-rep
    /// hot loop reads [`OpRef::name`] as a borrow and never allocates).
    pub op: String,
    pub p: usize,
    pub m: usize,
    pub bytes: usize,
    /// min over reps of (max over ranks) — the paper's statistic, µs.
    pub min_us: f64,
    pub mean_us: f64,
    pub stddev_us: f64,
    pub reps: usize,
}

/// Measure one exclusive-scan algorithm at vector length `m` on a
/// persistent [`World`] — the sweep-friendly entry point: the caller
/// amortizes the p thread spawns over every (algorithm, m) point.
///
/// In virtual-clock mode the result is deterministic, so a single
/// repetition (and no warmup) is executed regardless of `bench.reps`.
pub fn measure_exscan_world<T: Elem>(
    world: &World<T>,
    bench: &BenchConfig,
    algo: &dyn ScanAlgorithm<T>,
    op: &OpRef<T>,
    inputs: &[Vec<T>],
) -> Result<Measurement> {
    let p = world.size();
    assert_eq!(inputs.len(), p);
    let m = inputs[0].len();
    let virtual_mode = matches!(world.config().mode, ClockMode::Virtual(_));
    let overhead = match &world.config().mode {
        ClockMode::Virtual(model) => model.params.overhead,
        ClockMode::Real => 0.0,
    };
    let (warmups, reps) = if virtual_mode { (0, 1) } else { (bench.warmups, bench.reps) };

    // per-rank: Vec of per-rep times + the final output for validation.
    let per_rank = world.run(|ctx| {
        // Borrow the rank's input directly (no per-rank clone: at p = 1152,
        // m = 100 000 a clone would copy ~1 GB per measurement — §Perf).
        let input = &inputs[ctx.rank()];
        let mut output = vec![T::filler(); m];
        let mut times = Vec::with_capacity(reps);
        for _ in 0..warmups {
            ctx.barrier();
            algo.run(ctx, input, &mut output, op)?;
            if virtual_mode {
                ctx.reset_clock();
            }
        }
        for _ in 0..reps {
            // Synchronize with MPI_Barrier (twice), as the paper does.
            ctx.barrier();
            ctx.barrier();
            if virtual_mode {
                ctx.reset_clock();
            }
            let t0 = std::time::Instant::now();
            algo.run(ctx, input, &mut output, op)?;
            let dt = if virtual_mode {
                ctx.vclock() + overhead
            } else {
                t0.elapsed().as_secs_f64() * 1e6
            };
            times.push(dt);
        }
        Ok((times, output))
    })?;

    if bench.validate {
        let outputs: Vec<Vec<T>> = per_rank.iter().map(|(_, o)| o.clone()).collect();
        crate::coll::validate::assert_exscan_matches(inputs, op, &outputs);
    }

    // Per rep: max over ranks; over reps: Summary.
    let mut s = Summary::new();
    for rep in 0..reps {
        let worst = per_rank.iter().map(|(t, _)| t[rep]).fold(0.0f64, f64::max);
        s.push(worst);
    }
    Ok(Measurement {
        algo: algo.name().to_string(),
        op: op.name().to_string(),
        p,
        m,
        bytes: m * T::size_bytes(),
        min_us: s.min(),
        mean_us: s.mean(),
        stddev_us: s.stddev(),
        reps,
    })
}

/// One-shot convenience wrapper: build a world, measure one point, tear it
/// down. Prefer [`measure_exscan_world`] (or [`Harness::sweep`]) when
/// measuring more than one (algorithm, m) point per configuration.
pub fn measure_exscan<T: Elem>(
    world: &WorldConfig,
    bench: &BenchConfig,
    algo: &dyn ScanAlgorithm<T>,
    op: &OpRef<T>,
    inputs: &[Vec<T>],
) -> Result<Measurement> {
    let w = World::new(world.clone());
    measure_exscan_world(&w, bench, algo, op, inputs)
}

/// Convenience wrapper bundling a world + bench policy.
pub struct Harness {
    pub world: WorldConfig,
    pub bench: BenchConfig,
}

impl Harness {
    pub fn new(world: WorldConfig, bench: BenchConfig) -> Self {
        Harness { world, bench }
    }

    /// Measure several algorithms over several element counts.
    ///
    /// Spawns the rank threads exactly once for the whole sweep (verified
    /// by `tests/executor_spawn.rs::sweep_spawns_threads_once`): every
    /// (algorithm, m) point is a job submitted to the same [`World`].
    pub fn sweep<T: Elem>(
        &self,
        algos: &[&dyn ScanAlgorithm<T>],
        op: &OpRef<T>,
        m_values: &[usize],
        mk_inputs: impl Fn(usize, usize) -> Vec<Vec<T>>,
    ) -> Result<Vec<Measurement>> {
        let world: World<T> = World::new(self.world.clone());
        let mut out = Vec::new();
        for &m in m_values {
            let inputs = mk_inputs(world.size(), m);
            for algo in algos {
                out.push(measure_exscan_world(&world, &self.bench, *algo, op, &inputs)?);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::inputs_i64;
    use crate::coll::Exscan123;
    use crate::cost::CostParams;
    use crate::mpi::{ops, Topology};

    #[test]
    fn real_mode_measures_positive_times() {
        let world = WorldConfig::new(Topology::flat(4));
        let bench = BenchConfig { warmups: 1, reps: 5, validate: true };
        let inputs = inputs_i64(4, 64, 7);
        let m =
            measure_exscan(&world, &bench, &Exscan123, &ops::bxor(), &inputs).unwrap();
        assert!(m.min_us > 0.0);
        assert!(m.min_us <= m.mean_us);
        assert_eq!(m.reps, 5);
    }

    #[test]
    fn virtual_mode_single_rep_deterministic() {
        let world =
            WorldConfig::new(Topology::cluster(9, 1)).virtual_clock(CostParams::generic());
        let bench = BenchConfig::default();
        let inputs = inputs_i64(9, 16, 3);
        let a = measure_exscan(&world, &bench, &Exscan123, &ops::bxor(), &inputs).unwrap();
        let b = measure_exscan(&world, &bench, &Exscan123, &ops::bxor(), &inputs).unwrap();
        assert_eq!(a.reps, 1);
        assert_eq!(a.min_us, b.min_us, "virtual clock must be deterministic");
    }

    #[test]
    fn world_reuse_across_points_matches_one_shot() {
        // The persistent-executor path must produce the same deterministic
        // virtual-clock numbers as the one-shot path.
        let cfg =
            WorldConfig::new(Topology::cluster(8, 1)).virtual_clock(CostParams::generic());
        let bench = BenchConfig::default();
        let world: World<i64> = World::new(cfg.clone());
        for m in [1usize, 8, 64] {
            let inputs = inputs_i64(8, m, 11);
            let via_world =
                measure_exscan_world(&world, &bench, &Exscan123, &ops::bxor(), &inputs)
                    .unwrap();
            let one_shot =
                measure_exscan(&cfg, &bench, &Exscan123, &ops::bxor(), &inputs).unwrap();
            assert_eq!(via_world.min_us, one_shot.min_us, "m={m}");
        }
    }
}
