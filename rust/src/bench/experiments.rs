//! Paper-experiment runners: everything needed to regenerate Table 1 and
//! Figure 1 (experiments E1–E3 of DESIGN.md) on the simulated cluster.
//!
//! The three portable algorithms run under the calibrated α-β-γ parameters;
//! the *native* baseline runs the same recursive-doubling pattern under its
//! separately fitted (heavier) parameters — modelling mpich's internal
//! overheads, as calibrated from the paper's native column.

use anyhow::Result;

use super::harness::{measure_exscan_world, BenchConfig, Measurement};
use super::workload::{inputs_i64, SweepSpec};
use crate::coll::{Exscan123, ExscanMpich, ExscanOneDoubling, ExscanTwoOp, ScanAlgorithm};
use crate::cost::CostParams;
use crate::mpi::{ops, Topology, World, WorldConfig};

/// One of the paper's two cluster configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaperConfig {
    /// 36 nodes × 1 rank.
    C36x1,
    /// 36 nodes × 32 ranks = 1152.
    C36x32,
}

impl PaperConfig {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "36x1" => Some(PaperConfig::C36x1),
            "36x32" => Some(PaperConfig::C36x32),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PaperConfig::C36x1 => "36x1",
            PaperConfig::C36x32 => "36x32",
        }
    }

    pub fn topology(&self) -> Topology {
        match self {
            PaperConfig::C36x1 => Topology::cluster(36, 1),
            PaperConfig::C36x32 => Topology::cluster(36, 32),
        }
    }

    pub fn params(&self) -> CostParams {
        match self {
            PaperConfig::C36x1 => CostParams::paper_36x1(),
            PaperConfig::C36x32 => CostParams::paper_36x32(),
        }
    }

    pub fn native_params(&self) -> CostParams {
        match self {
            PaperConfig::C36x1 => CostParams::paper_36x1_native(),
            PaperConfig::C36x32 => CostParams::paper_36x32_native(),
        }
    }

    /// The paper's measured times for this config (for side-by-side
    /// reporting): `(m, native, two_op, one_doubling, otd123)`.
    pub fn paper_rows(&self) -> Vec<(usize, f64, f64, f64, f64)> {
        let d = match self {
            PaperConfig::C36x1 => &crate::cost::PAPER_TABLE1_36X1,
            PaperConfig::C36x32 => &crate::cost::PAPER_TABLE1_36X32,
        };
        (0..d.m.len())
            .map(|i| (d.m[i], d.native[i], d.two_op[i], d.one_doubling[i], d.otd123[i]))
            .collect()
    }
}

/// A Table-1 style row: measured (simulated) µs per algorithm.
#[derive(Debug, Clone)]
pub struct ExperimentRow {
    pub m: usize,
    pub native: f64,
    pub two_op: f64,
    pub one_doubling: f64,
    pub otd123: f64,
}

/// Run the four-algorithm comparison at the given element counts on the
/// simulated cluster; returns one row per m (this *is* Table 1).
pub fn table1_rows(config: PaperConfig, m_values: &[usize]) -> Result<Vec<ExperimentRow>> {
    let topo = config.topology();
    // Two persistent executors (the native baseline runs under its own
    // fitted cost model), each spawning its p rank threads exactly once
    // for the whole grid — not once per (algorithm, m) point (§Perf).
    let world: World<i64> =
        World::new(WorldConfig::new(topo).virtual_clock(config.params()));
    let native_world: World<i64> =
        World::new(WorldConfig::new(topo).virtual_clock(config.native_params()));
    // Validate outputs once per m (on the 123-doubling run); re-validating
    // all four algorithms would spend more time in the p·m-element oracle
    // than in the simulations themselves at p = 1152 (§Perf).
    let bench = BenchConfig { validate: false, ..BenchConfig::default() };
    let vbench = BenchConfig::default();
    let op = ops::bxor();

    let mut rows = Vec::with_capacity(m_values.len());
    for &m in m_values {
        let inputs = inputs_i64(topo.size(), m, 0xEC5CA7);
        let t = |w: &World<i64>, a: &dyn ScanAlgorithm<i64>, v: bool| -> Result<f64> {
            let b = if v { &vbench } else { &bench };
            Ok(measure_exscan_world(w, b, a, &op, &inputs)?.min_us)
        };
        rows.push(ExperimentRow {
            m,
            native: t(&native_world, &ExscanMpich, false)?,
            two_op: t(&world, &ExscanTwoOp, false)?,
            one_doubling: t(&world, &ExscanOneDoubling, false)?,
            otd123: t(&world, &Exscan123, true)?,
        });
    }
    Ok(rows)
}

/// The Figure 1 sweep: long-format measurements over the dense m grid for
/// all four algorithms. Returns measurements tagged by algorithm name.
pub fn figure1_sweep(config: PaperConfig, spec: &SweepSpec) -> Result<Vec<Measurement>> {
    let topo = config.topology();
    let world: World<i64> =
        World::new(WorldConfig::new(topo).virtual_clock(config.params()));
    let native_world: World<i64> =
        World::new(WorldConfig::new(topo).virtual_clock(config.native_params()));
    let bench = BenchConfig { validate: false, ..BenchConfig::default() };
    let vbench = BenchConfig::default();
    let op = ops::bxor();

    let mut out = Vec::new();
    for &m in &spec.m_values {
        let inputs = inputs_i64(topo.size(), m, 0xF16);
        out.push(measure_exscan_world(&native_world, &bench, &ExscanMpich, &op, &inputs)?);
        out.push(measure_exscan_world(&world, &bench, &ExscanTwoOp, &op, &inputs)?);
        out.push(measure_exscan_world(&world, &bench, &ExscanOneDoubling, &op, &inputs)?);
        out.push(measure_exscan_world(&world, &vbench, &Exscan123, &op, &inputs)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_36x1_shape() {
        // Small grid to keep the test fast; full grid runs in the bench.
        let rows = table1_rows(PaperConfig::C36x1, &[1, 1000, 100_000]).unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            // Headline shape: 123-doubling never loses to 1-doubling,
            // and never loses to the native baseline.
            assert!(r.otd123 <= r.one_doubling + 1e-9, "m={}", r.m);
            assert!(r.otd123 <= r.native + 1e-9, "m={}", r.m);
        }
        // At the largest size the two-⊕ penalty must show.
        let big = &rows[2];
        assert!(big.otd123 < big.two_op, "ops penalty at large m");
    }

    #[test]
    fn paper_rows_available() {
        let rows = PaperConfig::C36x1.paper_rows();
        assert_eq!(rows.len(), 6);
        assert_eq!(rows[0].0, 1);
        assert!((rows[5].2 - 1789.40).abs() < 1e-9);
    }
}
