//! Text-table, CSV and JSON rendering of measurement grids (the exact
//! row/column layout of the paper's Table 1, long-format CSV for Figure 1,
//! and the machine-readable `BENCH_hotpath.json` trajectory record).

use super::harness::Measurement;

/// Render measurements as an aligned text table: one row per element
/// count, one column per algorithm (paper Table 1 layout). Algorithms are
/// ordered by first appearance.
pub fn format_table(title: &str, ms: &[Measurement]) -> String {
    let mut algos: Vec<String> = Vec::new();
    for m in ms {
        if !algos.contains(&m.algo) {
            algos.push(m.algo.clone());
        }
    }
    let mut m_values: Vec<usize> = ms.iter().map(|m| m.m).collect();
    m_values.sort_unstable();
    m_values.dedup();

    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    out.push_str(&format!("{:>10}", "m"));
    for a in &algos {
        out.push_str(&format!(" {a:>16}"));
    }
    out.push('\n');
    out.push_str(&format!("{:>10}", ""));
    for _ in &algos {
        out.push_str(&format!(" {:>16}", "(µs)"));
    }
    out.push('\n');
    for &mv in &m_values {
        out.push_str(&format!("{mv:>10}"));
        for a in &algos {
            match ms.iter().find(|x| x.m == mv && &x.algo == a) {
                Some(x) => out.push_str(&format!(" {:>16.2}", x.min_us)),
                None => out.push_str(&format!(" {:>16}", "-")),
            }
        }
        out.push('\n');
    }
    out
}

/// Long-format CSV
/// (`config,algo,op,p,m,bytes,min_us,mean_us,stddev_us,reps`) suitable for
/// plotting Figure 1.
pub fn to_csv(config: &str, ms: &[Measurement]) -> String {
    let mut out = String::from("config,algo,op,p,m,bytes,min_us,mean_us,stddev_us,reps\n");
    for m in ms {
        out.push_str(&format!(
            "{},{},{},{},{},{},{:.4},{:.4},{:.4},{}\n",
            config, m.algo, m.op, m.p, m.m, m.bytes, m.min_us, m.mean_us, m.stddev_us, m.reps
        ));
    }
    out
}

/// One hot-path transport measurement: per-round message throughput of a
/// transport at world size `p` (see `benches/hotpath.rs`).
#[derive(Debug, Clone)]
pub struct HotpathPoint {
    /// Transport id: `"slot-pool"` (current) or `"legacy-mpmc"` (the v0
    /// Mutex+Condvar MPMC baseline, reconstructed in the bench).
    pub transport: String,
    pub p: usize,
    /// Rendezvous rounds timed per rank.
    pub rounds: usize,
    pub msgs_per_sec: f64,
    pub ns_per_round: f64,
}

/// One compute-path m-sweep measurement (see `benches/hotpath.rs`): a
/// whole-scan timing of `algo` at vector length `m`, under one of the
/// compared paths — `"fused"` / `"unfused"` (the A/B on the receive-reduce
/// primitives), `"chunked"` / `"flat"` (the large-m pipeline vs the flat
/// schedule), or `"block"` / `"rsag"` (the large-m engines riding the same
/// sweep for smoke coverage).
#[derive(Debug, Clone)]
pub struct MSweepPoint {
    /// Compared path id: `fused`, `unfused`, `chunked`, `flat`, `block`
    /// or `rsag`.
    pub path: String,
    pub algo: String,
    pub p: usize,
    pub m: usize,
    /// min over reps of (max over ranks), µs — the paper's statistic.
    pub min_us: f64,
    /// Aggregated ⊕ applications observed by the sharded op counters over
    /// the whole measurement (warmups + reps).
    pub ops: u64,
}

/// One kernel-sweep measurement (see `benches/hotpath.rs`): a single ⊕
/// application of `op` over an m-element slice, under slice-kernel
/// dispatch (`"slice"`, the resolved `OpKernel` path) or the per-element
/// reference (`"per-element"`, `CombineOp::combine` through the same
/// handle). The two paths are asserted bit-identical before timing.
#[derive(Debug, Clone)]
pub struct KernelPoint {
    pub op: String,
    /// Compared dispatch path: `slice` or `per-element`.
    pub path: String,
    pub m: usize,
    pub ns_per_apply: f64,
    /// Elements combined per second (m / ns_per_apply, scaled).
    pub elems_per_sec: f64,
}

/// One inbox latency-sweep measurement (see `benches/hotpath.rs`): ring
/// rendezvous ns/round under the adaptive per-slot spin budget
/// (`"adaptive"`) vs the fixed pre-adaptive budget (`"fixed-spin"`,
/// `WorldConfig::with_fixed_spin`), with the aggregate receiver-side
/// spin-probe/park counters over the whole run (warmup included).
#[derive(Debug, Clone)]
pub struct LatencyPoint {
    /// Compared spin policy: `adaptive` or `fixed-spin`.
    pub mode: String,
    pub p: usize,
    /// Rendezvous rounds timed per rank.
    pub rounds: usize,
    pub ns_per_round: f64,
    pub spins: u64,
    pub parks: u64,
}

/// One scan-service batching measurement (see `benches/hotpath.rs`): K
/// small-m requests through the engine, batched (one flush for all K)
/// vs serial (one flush per request), wall time per request plus the
/// deterministic rounds/request the batcher achieved.
#[derive(Debug, Clone)]
pub struct SvcPoint {
    pub k: usize,
    pub p: usize,
    pub m: usize,
    pub batched_us_per_req: f64,
    pub serial_us_per_req: f64,
    /// Amortized rounds/request of the batched run (closed form:
    /// `rounds(p) / K` when all K coalesce into one collective).
    pub batched_rounds_per_req: f64,
    /// Rounds/request of the serial run (= `rounds(p)`).
    pub serial_rounds_per_req: f64,
}

/// One scan-service latency measurement (see `benches/hotpath.rs`): a
/// sustained submit stream through the engine under one scenario
/// (`"baseline"` clean run, `"rank-death"` with a seeded mid-run kill),
/// with the engine's histogram-derived latency quantiles and failure
/// accounting. The quantiles are the SLO-gated numbers.
#[derive(Debug, Clone)]
pub struct SvcLatencyPoint {
    /// Scenario id: `baseline` or `rank-death`.
    pub scenario: String,
    pub p: usize,
    /// Requests submitted over the scenario.
    pub requests: u64,
    /// Histogram quantiles (µs, conservative bucket upper bounds).
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub failed: u64,
    /// Requests failed with an attributed `RankFailed`.
    pub rank_failures: u64,
    pub worlds_rebuilt: u64,
}

/// One soak measurement (see `benches/hotpath.rs`): a sustained mixed
/// workload with periodic seeded rank death, checking the zero-lost-
/// requests invariant (`submitted == completed + failed`), flat memory
/// (pool-miss growth between the mid-point and the end of the soak) and
/// the tail-latency SLO.
#[derive(Debug, Clone)]
pub struct SoakPoint {
    pub seed: u64,
    pub p: usize,
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    pub rank_deaths: u64,
    pub worlds_rebuilt: u64,
    pub p99_us: f64,
    /// Pool misses accrued in the second half of the soak (steady state
    /// ⇒ ~0: the pools recycle instead of allocating).
    pub pool_miss_delta: u64,
}

/// One large-m selection-sweep measurement (see `benches/hotpath.rs`):
/// at world size `p` and vector length `m`, the algorithm
/// [`crate::coll::select_exscan`] picked under the calibrated paper
/// parameters, the closed-form argmin over the candidate pool at the
/// same point, and both predicted times. Selection is honest iff
/// `selected == argmin` at every sweep point — the crossover gate in the
/// bench asserts exactly that, and the recorded rows make the
/// round-regime → bandwidth-regime boundary visible in the trajectory.
#[derive(Debug, Clone)]
pub struct CrossoverPoint {
    pub p: usize,
    pub m: usize,
    /// Algorithm `select_exscan` actually picked at this (p, m).
    pub selected: String,
    /// Closed-form argmin over `select_candidates` at the same point.
    pub argmin: String,
    /// Predicted completion of the selected algorithm (µs).
    pub selected_us: f64,
    /// Predicted completion of the argmin (µs) — equals `selected_us`
    /// whenever selection is honest.
    pub argmin_us: f64,
}

/// One topology-sweep measurement (see `benches/hotpath.rs`): at a given
/// topology preset (identified by spec name + seed + matrix digest, so
/// the exact per-link matrix is replayable), the virtual-clock completion
/// of the two-level scheme vs flat 123-doubling, plus what the
/// topology-aware selection picked. The bench gates that `two_level_us <
/// flat123_us` on every hierarchical preset and never on the uniform one,
/// and that `selected` is `two-level` exactly where hierarchy exists.
#[derive(Debug, Clone)]
pub struct TopoSweepPoint {
    /// Topology spec (`"2level:4x9"`, `"flat:36"`, …).
    pub topo: String,
    pub seed: u64,
    /// FNV-1a digest of the per-link matrix — the replay fingerprint.
    pub digest: u64,
    pub p: usize,
    pub m: usize,
    /// Virtual-clock completion of `ExscanTwoLevel` (µs).
    pub two_level_us: f64,
    /// Virtual-clock completion of flat `Exscan123` (µs).
    pub flat123_us: f64,
    /// Algorithm `select_exscan_topo` picked at this point.
    pub selected: String,
}

/// One wire-fault overhead measurement (see `benches/hotpath.rs`): the
/// same rendezvous workload on a wire backend with the seeded fault plan
/// armed (recovery on) vs clean, plus the recovery counters and the
/// replayable fault digest. The bench gates that every faulted run still
/// verified bit-exactly (`verified` true) — the overhead column is only
/// meaningful if the repaired stream stayed correct.
#[derive(Debug, Clone)]
pub struct WireFaultPoint {
    /// Wire backend id (`"shm"`, `"uds"`).
    pub backend: String,
    pub seed: u64,
    pub p: usize,
    pub m: usize,
    /// Clean (no fault plan) completion, µs.
    pub clean_us: f64,
    /// Faulted-with-recovery completion, µs.
    pub faulted_us: f64,
    pub injected: u64,
    pub retransmits: u64,
    pub reconnects: u64,
    pub dropped_dups: u64,
    /// XOR'd `WireFaultReport` digest — the replay fingerprint.
    pub fault_digest: u64,
    /// Whether the faulted run verified bit-exactly against the oracle.
    pub verified: bool,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Serialize hot-path measurements as the `BENCH_hotpath.json` document —
/// the repo's machine-readable perf-trajectory record. Hand-rolled (no
/// serde in this offline build); stable key order so diffs stay readable.
/// Schema v2 added the `m_sweep` section (fused-vs-unfused and
/// chunked-vs-flat compute-path points); v3 added `svc_sweep` (scan-service
/// batched-vs-serial throughput and amortized rounds/request); v4 adds
/// `kernel_sweep` (slice-kernel vs per-element ⊕ dispatch per op × m) and
/// `latency_sweep` (adaptive vs fixed inbox spin budget per p, with
/// spin/park counters); v5 adds `svc_latency` (service p50/p99/p999
/// under baseline and rank-death scenarios — the SLO-gated numbers) and
/// `soak` (sustained mixed workload with periodic rank death:
/// zero-lost-requests and flat-memory evidence); v6 adds `m_crossover`
/// (the large-m selection sweep: `select_exscan`'s pick vs the
/// closed-form argmin over the candidate pool at each (p, m), tracing
/// the round-regime → bandwidth-regime boundary); v7 adds `topo_sweep`
/// (two-level vs flat 123-doubling virtual-clock completion per topology
/// preset × m, with the matrix digest and the topology-aware selection);
/// v8 adds `wire_fault` (recovered-vs-clean overhead per wire backend
/// under the seeded fault plan, with retransmit/reconnect/dup counters
/// and the replayable fault digest — every row oracle-verified).
#[allow(clippy::too_many_arguments)]
pub fn hotpath_json(
    meta: &[(&str, String)],
    points: &[HotpathPoint],
    m_sweep: &[MSweepPoint],
    svc_sweep: &[SvcPoint],
    kernel_sweep: &[KernelPoint],
    latency_sweep: &[LatencyPoint],
    svc_latency: &[SvcLatencyPoint],
    soak: &[SoakPoint],
    m_crossover: &[CrossoverPoint],
    topo_sweep: &[TopoSweepPoint],
    wire_fault: &[WireFaultPoint],
) -> String {
    let mut out = String::from("{\n  \"schema\": \"exscan-hotpath-v8\",\n  \"meta\": {");
    for (i, (k, v)) in meta.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\n    \"{}\": \"{}\"", json_escape(k), json_escape(v)));
    }
    out.push_str("\n  },\n  \"points\": [");
    for (i, pt) in points.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"transport\": \"{}\", \"p\": {}, \"rounds\": {}, \
             \"msgs_per_sec\": {:.1}, \"ns_per_round\": {:.1}}}",
            json_escape(&pt.transport),
            pt.p,
            pt.rounds,
            pt.msgs_per_sec,
            pt.ns_per_round
        ));
    }
    out.push_str("\n  ],\n  \"m_sweep\": [");
    for (i, pt) in m_sweep.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"path\": \"{}\", \"algo\": \"{}\", \"p\": {}, \"m\": {}, \
             \"min_us\": {:.3}, \"ops\": {}}}",
            json_escape(&pt.path),
            json_escape(&pt.algo),
            pt.p,
            pt.m,
            pt.min_us,
            pt.ops
        ));
    }
    out.push_str("\n  ],\n  \"svc_sweep\": [");
    for (i, pt) in svc_sweep.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"k\": {}, \"p\": {}, \"m\": {}, \"batched_us_per_req\": {:.3}, \
             \"serial_us_per_req\": {:.3}, \"batched_rounds_per_req\": {:.4}, \
             \"serial_rounds_per_req\": {:.4}}}",
            pt.k,
            pt.p,
            pt.m,
            pt.batched_us_per_req,
            pt.serial_us_per_req,
            pt.batched_rounds_per_req,
            pt.serial_rounds_per_req
        ));
    }
    out.push_str("\n  ],\n  \"kernel_sweep\": [");
    for (i, pt) in kernel_sweep.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"op\": \"{}\", \"path\": \"{}\", \"m\": {}, \
             \"ns_per_apply\": {:.2}, \"elems_per_sec\": {:.1}}}",
            json_escape(&pt.op),
            json_escape(&pt.path),
            pt.m,
            pt.ns_per_apply,
            pt.elems_per_sec
        ));
    }
    out.push_str("\n  ],\n  \"latency_sweep\": [");
    for (i, pt) in latency_sweep.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"mode\": \"{}\", \"p\": {}, \"rounds\": {}, \
             \"ns_per_round\": {:.1}, \"spins\": {}, \"parks\": {}}}",
            json_escape(&pt.mode),
            pt.p,
            pt.rounds,
            pt.ns_per_round,
            pt.spins,
            pt.parks
        ));
    }
    out.push_str("\n  ],\n  \"svc_latency\": [");
    for (i, pt) in svc_latency.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"scenario\": \"{}\", \"p\": {}, \"requests\": {}, \
             \"p50_us\": {:.3}, \"p99_us\": {:.3}, \"p999_us\": {:.3}, \
             \"failed\": {}, \"rank_failures\": {}, \"worlds_rebuilt\": {}}}",
            json_escape(&pt.scenario),
            pt.p,
            pt.requests,
            pt.p50_us,
            pt.p99_us,
            pt.p999_us,
            pt.failed,
            pt.rank_failures,
            pt.worlds_rebuilt
        ));
    }
    out.push_str("\n  ],\n  \"soak\": [");
    for (i, pt) in soak.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"seed\": {}, \"p\": {}, \"submitted\": {}, \"completed\": {}, \
             \"failed\": {}, \"rejected\": {}, \"rank_deaths\": {}, \
             \"worlds_rebuilt\": {}, \"p99_us\": {:.3}, \"pool_miss_delta\": {}}}",
            pt.seed,
            pt.p,
            pt.submitted,
            pt.completed,
            pt.failed,
            pt.rejected,
            pt.rank_deaths,
            pt.worlds_rebuilt,
            pt.p99_us,
            pt.pool_miss_delta
        ));
    }
    out.push_str("\n  ],\n  \"m_crossover\": [");
    for (i, pt) in m_crossover.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"p\": {}, \"m\": {}, \"selected\": \"{}\", \"argmin\": \"{}\", \
             \"selected_us\": {:.4}, \"argmin_us\": {:.4}}}",
            pt.p,
            pt.m,
            json_escape(&pt.selected),
            json_escape(&pt.argmin),
            pt.selected_us,
            pt.argmin_us
        ));
    }
    out.push_str("\n  ],\n  \"topo_sweep\": [");
    for (i, pt) in topo_sweep.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"topo\": \"{}\", \"seed\": {}, \"digest\": \"{:#018x}\", \
             \"p\": {}, \"m\": {}, \"two_level_us\": {:.4}, \"flat123_us\": {:.4}, \
             \"selected\": \"{}\"}}",
            json_escape(&pt.topo),
            pt.seed,
            pt.digest,
            pt.p,
            pt.m,
            pt.two_level_us,
            pt.flat123_us,
            json_escape(&pt.selected)
        ));
    }
    out.push_str("\n  ],\n  \"wire_fault\": [");
    for (i, pt) in wire_fault.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"backend\": \"{}\", \"seed\": {}, \"p\": {}, \"m\": {}, \
             \"clean_us\": {:.3}, \"faulted_us\": {:.3}, \"injected\": {}, \
             \"retransmits\": {}, \"reconnects\": {}, \"dropped_dups\": {}, \
             \"fault_digest\": \"{:#018x}\", \"verified\": {}}}",
            json_escape(&pt.backend),
            pt.seed,
            pt.p,
            pt.m,
            pt.clean_us,
            pt.faulted_us,
            pt.injected,
            pt.retransmits,
            pt.reconnects,
            pt.dropped_dups,
            pt.fault_digest,
            pt.verified
        ));
    }
    out.push_str("\n  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(algo: &str, m: usize, t: f64) -> Measurement {
        Measurement {
            algo: algo.into(),
            op: "bxor_i64".into(),
            p: 36,
            m,
            bytes: m * 8,
            min_us: t,
            mean_us: t * 1.1,
            stddev_us: 0.5,
            reps: 10,
        }
    }

    #[test]
    fn table_layout() {
        let ms = vec![mk("a", 1, 1.0), mk("b", 1, 2.0), mk("a", 10, 3.0), mk("b", 10, 4.0)];
        let t = format_table("T", &ms);
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines[0].contains('T'));
        assert!(lines[1].contains('a') && lines[1].contains('b'));
        assert_eq!(lines.len(), 5); // title, header, units, two data rows
        assert!(lines[3].trim_start().starts_with('1'));
    }

    #[test]
    fn csv_roundtrip_fields() {
        let csv = to_csv("36x1", &[mk("x", 5, 9.25)]);
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "config,algo,op,p,m,bytes,min_us,mean_us,stddev_us,reps"
        );
        let row = lines.next().unwrap();
        assert!(row.starts_with("36x1,x,bxor_i64,36,5,40,9.2500,"));
    }

    #[test]
    fn hotpath_json_shape() {
        let points = vec![
            HotpathPoint {
                transport: "slot-pool".into(),
                p: 4,
                rounds: 1000,
                msgs_per_sec: 1.25e6,
                ns_per_round: 800.0,
            },
            HotpathPoint {
                transport: "legacy-mpmc".into(),
                p: 4,
                rounds: 1000,
                msgs_per_sec: 5.0e5,
                ns_per_round: 2000.0,
            },
        ];
        let sweep = vec![MSweepPoint {
            path: "fused".into(),
            algo: "123-doubling".into(),
            p: 8,
            m: 4096,
            min_us: 123.456,
            ops: 720,
        }];
        let svc = vec![SvcPoint {
            k: 16,
            p: 8,
            m: 8,
            batched_us_per_req: 12.5,
            serial_us_per_req: 80.0,
            batched_rounds_per_req: 0.25,
            serial_rounds_per_req: 4.0,
        }];
        let kernels = vec![KernelPoint {
            op: "bxor_i64".into(),
            path: "slice".into(),
            m: 4096,
            ns_per_apply: 512.25,
            elems_per_sec: 8.0e9,
        }];
        let lat = vec![LatencyPoint {
            mode: "adaptive".into(),
            p: 16,
            rounds: 2000,
            ns_per_round: 950.0,
            spins: 123456,
            parks: 7,
        }];
        let svc_lat = vec![SvcLatencyPoint {
            scenario: "rank-death".into(),
            p: 8,
            requests: 512,
            p50_us: 100.0,
            p99_us: 750.5,
            p999_us: 4000.0,
            failed: 3,
            rank_failures: 3,
            worlds_rebuilt: 1,
        }];
        let soak = vec![SoakPoint {
            seed: 11,
            p: 8,
            submitted: 4096,
            completed: 4000,
            failed: 96,
            rejected: 12,
            rank_deaths: 2,
            worlds_rebuilt: 2,
            p99_us: 900.25,
            pool_miss_delta: 0,
        }];
        let crossover = vec![CrossoverPoint {
            p: 256,
            m: 1 << 20,
            selected: "rsag".into(),
            argmin: "rsag".into(),
            selected_us: 1234.5,
            argmin_us: 1234.5,
        }];
        let topo = vec![TopoSweepPoint {
            topo: "2level:4x9".into(),
            seed: 7,
            digest: 0x1234_5678_9abc_def0,
            p: 36,
            m: 4,
            two_level_us: 24.5,
            flat123_us: 60.25,
            selected: "two-level".into(),
        }];
        let wire = vec![WireFaultPoint {
            backend: "shm".into(),
            seed: 0xA11CE,
            p: 4,
            m: 64,
            clean_us: 42.125,
            faulted_us: 63.5,
            injected: 19,
            retransmits: 11,
            reconnects: 1,
            dropped_dups: 3,
            fault_digest: 0x0fed_cba9_8765_4321,
            verified: true,
        }];
        let j = hotpath_json(
            &[("host", "ci \"runner\"".to_string())],
            &points,
            &sweep,
            &svc,
            &kernels,
            &lat,
            &svc_lat,
            &soak,
            &crossover,
            &topo,
            &wire,
        );
        assert!(j.contains("\"schema\": \"exscan-hotpath-v8\""), "{j}");
        assert!(j.contains("\"wire_fault\""), "{j}");
        assert!(j.contains("\"backend\": \"shm\""), "{j}");
        assert!(j.contains("\"retransmits\": 11"), "{j}");
        assert!(j.contains("\"fault_digest\": \"0x0fedcba987654321\""), "{j}");
        assert!(j.contains("\"verified\": true"), "{j}");
        assert!(j.contains("\"topo_sweep\""), "{j}");
        assert!(j.contains("\"topo\": \"2level:4x9\""), "{j}");
        assert!(j.contains("\"digest\": \"0x123456789abcdef0\""), "{j}");
        assert!(j.contains("\"two_level_us\": 24.5000"), "{j}");
        assert!(j.contains("\"selected\": \"two-level\""), "{j}");
        assert!(j.contains("\"m_crossover\""), "{j}");
        assert!(j.contains("\"selected\": \"rsag\""), "{j}");
        assert!(j.contains("\"argmin_us\": 1234.5000"), "{j}");
        assert!(j.contains("\"svc_latency\""), "{j}");
        assert!(j.contains("\"scenario\": \"rank-death\""), "{j}");
        assert!(j.contains("\"p999_us\": 4000.000"), "{j}");
        assert!(j.contains("\"soak\""), "{j}");
        assert!(j.contains("\"rank_deaths\": 2"), "{j}");
        assert!(j.contains("\"pool_miss_delta\": 0"), "{j}");
        assert!(j.contains("\"kernel_sweep\""), "{j}");
        assert!(j.contains("\"path\": \"slice\""), "{j}");
        assert!(j.contains("\"ns_per_apply\": 512.25"), "{j}");
        assert!(j.contains("\"latency_sweep\""), "{j}");
        assert!(j.contains("\"mode\": \"adaptive\""), "{j}");
        assert!(j.contains("\"parks\": 7"), "{j}");
        assert!(j.contains("\"transport\": \"slot-pool\""), "{j}");
        assert!(j.contains("\"msgs_per_sec\": 1250000.0"), "{j}");
        assert!(j.contains("ci \\\"runner\\\""), "{j}");
        assert!(j.contains("\"path\": \"fused\""), "{j}");
        assert!(j.contains("\"min_us\": 123.456"), "{j}");
        assert!(j.contains("\"ops\": 720"), "{j}");
        assert!(j.contains("\"svc_sweep\""), "{j}");
        assert!(j.contains("\"batched_rounds_per_req\": 0.2500"), "{j}");
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }
}
