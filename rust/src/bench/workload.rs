//! Deterministic workload generators for benchmarks and tests.

use crate::coll::segmented::Seg;
use crate::mpi::Rec2;
use crate::util::Rng;

/// Per-rank i64 vectors, deterministic in (seed, rank).
pub fn inputs_i64(p: usize, m: usize, seed: u64) -> Vec<Vec<i64>> {
    (0..p)
        .map(|r| {
            let mut rng = Rng::seed_from_u64(seed ^ (r as u64).wrapping_mul(0x9E37_79B9));
            (0..m).map(|_| rng.gen_i64()).collect()
        })
        .collect()
}

/// Per-rank segmented i64 vectors: deterministic values with ~1/4 of the
/// elements flagged as segment starts (so segment boundaries fall at
/// arbitrary (rank, lane) positions — the shape that stresses the lifted
/// operator's non-commutative flag rule).
pub fn inputs_seg_i64(p: usize, m: usize, seed: u64) -> Vec<Vec<Seg<i64>>> {
    (0..p)
        .map(|r| {
            let mut rng = Rng::seed_from_u64(seed ^ (r as u64).wrapping_mul(0x1656_67B1));
            (0..m)
                .map(|_| {
                    let flag = (rng.gen_i64() & 3) == 0;
                    Seg::new(flag, rng.gen_i64())
                })
                .collect()
        })
        .collect()
}

/// Per-rank well-conditioned affine recurrence elements: matrices close to
/// a rotation (determinant ≈ 1) so long compositions neither explode nor
/// vanish and float comparisons stay meaningful.
pub fn inputs_rec2(p: usize, m: usize, seed: u64) -> Vec<Vec<Rec2>> {
    (0..p)
        .map(|r| {
            let mut rng = Rng::seed_from_u64(seed ^ (r as u64).wrapping_mul(0xC2B2_AE35));
            (0..m)
                .map(|_| {
                    let th: f32 = rng.gen_range_f32(-0.1, 0.1);
                    let (s, c) = th.sin_cos();
                    Rec2::new(
                        [c, -s, s, c],
                        [rng.gen_range_f32(-1.0, 1.0), rng.gen_range_f32(-1.0, 1.0)],
                    )
                })
                .collect()
        })
        .collect()
}

/// Declarative sweep: which element counts to measure. The paper's Table 1
/// grid plus a denser grid for the Figure 1 curves.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub m_values: Vec<usize>,
}

impl SweepSpec {
    /// Table 1 grid: 1, 10, …, 100 000 elements.
    pub fn table1() -> Self {
        SweepSpec { m_values: vec![1, 10, 100, 1000, 10_000, 100_000] }
    }

    /// Figure 1 grid: denser, roughly 3 points per decade, plus m = 0
    /// (the paper's plot starts at 0 bytes).
    pub fn figure1() -> Self {
        SweepSpec {
            m_values: vec![
                0, 1, 2, 5, 10, 20, 50, 100, 200, 500, 1000, 2000, 5000, 10_000, 20_000, 50_000,
                100_000,
            ],
        }
    }

    /// A quick grid for CI.
    pub fn quick() -> Self {
        SweepSpec { m_values: vec![1, 100, 10_000] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(inputs_i64(4, 8, 42), inputs_i64(4, 8, 42));
        assert_ne!(inputs_i64(4, 8, 42), inputs_i64(4, 8, 43));
    }

    #[test]
    fn shapes() {
        let v = inputs_i64(5, 7, 1);
        assert_eq!(v.len(), 5);
        assert!(v.iter().all(|x| x.len() == 7));
        let r = inputs_rec2(3, 4, 1);
        assert_eq!(r.len(), 3);
        assert!(r.iter().all(|x| x.len() == 4));
    }

    #[test]
    fn seg_inputs_mix_flags_deterministically() {
        let a = inputs_seg_i64(5, 64, 7);
        assert_eq!(a, inputs_seg_i64(5, 64, 7));
        assert_ne!(a, inputs_seg_i64(5, 64, 8));
        let flags: usize =
            a.iter().flat_map(|v| v.iter()).filter(|s| s.flag).count();
        let total = 5 * 64;
        assert!(flags > total / 10 && flags < total / 2, "{flags}/{total}");
    }

    #[test]
    fn rec2_well_conditioned() {
        // Determinant of each matrix ≈ 1 (rotation).
        for row in inputs_rec2(4, 16, 9) {
            for e in row {
                let det = e.a[0] * e.a[3] - e.a[1] * e.a[2];
                assert!((det - 1.0).abs() < 1e-3);
            }
        }
    }
}
