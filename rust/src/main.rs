//! `exscan` — the launcher binary. See `exscan help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = exscan::cli::run_argv(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
