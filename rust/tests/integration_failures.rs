//! Failure injection: the substrate must fail *loudly and promptly* on
//! broken coordination — a deadlocked receive reports who was waiting for
//! what instead of hanging the suite.
//!
//! Run in its own test binary because it shortens the global receive
//! timeout via `EXSCAN_RECV_TIMEOUT_MS` (process-wide, read once).

use exscan::mpi::{run_world, Topology, WorldConfig};

fn set_short_timeout() {
    // Read-once: setting it repeatedly is fine, the first reader wins.
    std::env::set_var("EXSCAN_RECV_TIMEOUT_MS", "300");
}

#[test]
fn deadlocked_recv_reports_context() {
    set_short_timeout();
    let cfg = WorldConfig::new(Topology::flat(2));
    let t0 = std::time::Instant::now();
    let res = run_world::<i64, (), _>(&cfg, |ctx| {
        if ctx.rank() == 1 {
            // Wait for a message nobody sends.
            let mut buf = [0i64];
            ctx.recv(7, 0, &mut buf)?;
        }
        Ok(())
    });
    let err = format!("{:#}", res.unwrap_err());
    assert!(err.contains("deadlocked"), "unexpected error: {err}");
    assert!(err.contains("round=7"), "missing round in: {err}");
    assert!(t0.elapsed() < std::time::Duration::from_secs(30), "must fail fast");
}

#[test]
fn size_mismatch_is_an_error_not_corruption() {
    set_short_timeout();
    let cfg = WorldConfig::new(Topology::flat(2));
    let res = run_world::<i64, (), _>(&cfg, |ctx| {
        if ctx.rank() == 0 {
            ctx.send(0, 1, &[1i64, 2, 3])?;
        } else {
            let mut buf = [0i64; 2]; // wrong size
            ctx.recv(0, 0, &mut buf)?;
        }
        Ok(())
    });
    let err = format!("{:#}", res.unwrap_err());
    assert!(err.contains("size mismatch"), "unexpected error: {err}");
}

#[test]
fn wrong_round_tag_never_matches() {
    set_short_timeout();
    let cfg = WorldConfig::new(Topology::flat(2));
    let res = run_world::<i64, (), _>(&cfg, |ctx| {
        let mut buf = [0i64];
        if ctx.rank() == 0 {
            ctx.send(3, 1, &buf)?; // round 3…
        } else {
            ctx.recv(4, 0, &mut buf)?; // …can never satisfy round 4
        }
        Ok(())
    });
    assert!(res.is_err(), "round-tag matching must be strict");
}

#[test]
fn panic_in_one_rank_fails_the_world() {
    set_short_timeout();
    let cfg = WorldConfig::new(Topology::flat(4));
    let res = run_world::<i64, (), _>(&cfg, |ctx| {
        if ctx.rank() == 3 {
            panic!("injected rank failure");
        }
        Ok(())
    });
    let err = format!("{:#}", res.unwrap_err());
    assert!(err.contains("injected rank failure"), "{err}");
}
