//! Property suite for the slice-kernel ⊕ engine: for every registered
//! operator, `CombineOp::combine_slice` (and the resolved `OpKernel`
//! dispatch built on it) must be **bit-identical** to the per-element
//! `combine` reference — across the satellite m grid {0, 1, 17, 4096},
//! random inputs, and both operand layouts. Bit-identity (not tolerance)
//! is the point: the kernels re-express the same scalar arithmetic in an
//! autovectorizable loop, and any reassociation, operand swap or
//! off-by-one in a tight loop shows up here — including for the
//! non-commutative `rec2_compose` and the direction-sensitive lifted
//! segmented operators. The world-level A/B
//! (`WorldConfig::with_per_element_ops`) is then pinned end to end:
//! identical outputs, traces and ⊕ counts for every exscan algorithm.

use exscan::coll::{
    all_exscan_algorithms, seg_bxor_i64, seg_max_i64, seg_sum_i64, ExscanBlock, ExscanChunked,
    ExscanHierarchical, ExscanTwoLevel, Seg,
};
use exscan::prelude::*;
use exscan::util::quickcheck::{cases, forall, Gen};

/// The satellite's m grid: empty, single element, odd small, one memory
/// page's worth (the autovectorized regime).
const MS: [usize; 4] = [0, 1, 17, 4096];

/// Assert the three dispatch paths (static-or-dyn slice kernel via
/// `OpKernel`, raw `reduce_local_sharded`, per-element reference) agree
/// bit-for-bit and each count exactly one application.
fn assert_dispatch_equiv<T: Elem>(op: &OpRef<T>, input: &[T], base: &[T]) {
    let before = op.applications();
    let mut slice = base.to_vec();
    op.kernel().apply_sharded(1, input, &mut slice);
    let mut pe = base.to_vec();
    op.kernel_per_element().apply_sharded(2, input, &mut pe);
    let mut sharded = base.to_vec();
    op.reduce_local_sharded(3, input, &mut sharded);
    assert_eq!(
        slice,
        pe,
        "op {} m {}: slice kernel != per-element reference",
        op.name(),
        input.len()
    );
    assert_eq!(
        slice,
        sharded,
        "op {} m {}: reduce_local_sharded != kernel path",
        op.name(),
        input.len()
    );
    assert_eq!(
        op.applications(),
        before + 3,
        "op {}: every dispatch path must count exactly once",
        op.name()
    );
}

#[test]
fn slice_kernels_match_per_element_i64_ops() {
    let mk: Vec<fn() -> OpRef<i64>> = vec![
        ops::bxor,
        ops::bor,
        ops::sum_i64,
        ops::max_i64,
        ops::min_i64,
        || ops::expensive_bxor(16), // dyn-slice fallback path
    ];
    forall(cases(10), |g| {
        for &m in &MS {
            let input: Vec<i64> = (0..m).map(|_| g.i64()).collect();
            let base: Vec<i64> = (0..m).map(|_| g.i64()).collect();
            for f in &mk {
                assert_dispatch_equiv(&f(), &input, &base);
            }
        }
    });
}

#[test]
fn slice_kernels_match_per_element_u64_sum() {
    forall(cases(10), |g| {
        for &m in &MS {
            let input: Vec<u64> = (0..m).map(|_| g.u64()).collect();
            let base: Vec<u64> = (0..m).map(|_| g.u64()).collect();
            assert_dispatch_equiv(&ops::sum_u64(), &input, &base);
        }
    });
}

#[test]
fn slice_kernel_matches_per_element_f64_sum_bitwise() {
    // PartialEq would already fail on any value drift; additionally pin
    // exact bit patterns (−0.0 vs 0.0, NaN payloads aside) since float
    // reassociation is the classic vectorization hazard.
    forall(cases(10), |g| {
        for &m in &MS {
            let input: Vec<f64> = (0..m).map(|_| g.f32_in(-1e6, 1e6) as f64).collect();
            let base: Vec<f64> = (0..m).map(|_| g.f32_in(-1e6, 1e6) as f64).collect();
            let op = ops::sum_f64();
            let mut slice = base.clone();
            op.kernel().apply_sharded(0, &input, &mut slice);
            let mut pe = base.clone();
            op.kernel_per_element().apply_sharded(0, &input, &mut pe);
            let sb: Vec<u64> = slice.iter().map(|x| x.to_bits()).collect();
            let pb: Vec<u64> = pe.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, pb, "sum_f64 m {m}: slice kernel not bit-identical");
        }
    });
}

fn rec2_of(g: &mut Gen) -> Rec2 {
    Rec2::new(
        [
            g.f32_in(-2.0, 2.0),
            g.f32_in(-2.0, 2.0),
            g.f32_in(-2.0, 2.0),
            g.f32_in(-2.0, 2.0),
        ],
        [g.f32_in(-4.0, 4.0), g.f32_in(-4.0, 4.0)],
    )
}

#[test]
fn slice_kernel_matches_per_element_rec2_compose() {
    // Non-commutative: the kernel must keep `input` as the earlier map.
    forall(cases(10), |g| {
        for &m in &MS {
            let input: Vec<Rec2> = (0..m).map(|_| rec2_of(g)).collect();
            let base: Vec<Rec2> = (0..m).map(|_| rec2_of(g)).collect();
            assert_dispatch_equiv(&ops::rec2_compose(), &input, &base);
        }
    });
}

#[test]
fn slice_dispatch_matches_per_element_lifted_segmented() {
    // The lifted operators have no static kernel: this pins the dyn
    // `combine_slice` default (monomorphized forward to `combine`)
    // against the reference, flag rule included.
    let mk: Vec<fn() -> OpRef<Seg<i64>>> = vec![seg_bxor_i64, seg_sum_i64, seg_max_i64];
    forall(cases(10), |g| {
        for &m in &MS {
            let input: Vec<Seg<i64>> =
                (0..m).map(|_| Seg::new(g.bool(), g.i64())).collect();
            let base: Vec<Seg<i64>> =
                (0..m).map(|_| Seg::new(g.bool(), g.i64())).collect();
            for f in &mk {
                assert_dispatch_equiv(&f(), &input, &base);
            }
        }
    });
}

/// The prefix-scan kernels (`OpKernel::scan_sharded`, used by the block
/// and rsag engines' local-scan phase) vs the per-element fold reference:
/// bit-identical promoted rows and identical application counts (n−1 per
/// launch) on both dispatch paths.
fn assert_scan_equiv<T: Elem>(op: &OpRef<T>, rows: &[T], width: usize, n: usize) {
    let before = op.applications();
    let mut fast = rows.to_vec();
    op.kernel().scan_sharded(1, &mut fast, width, n);
    let mut pe = rows.to_vec();
    op.kernel_per_element().scan_sharded(2, &mut pe, width, n);
    assert_eq!(
        fast,
        pe,
        "op {} n {n} width {width}: scan kernel != per-element fold",
        op.name()
    );
    let per_launch = n.saturating_sub(1) as u64;
    assert_eq!(
        op.applications(),
        before + 2 * per_launch,
        "op {} n {n} width {width}: scan launches must count n−1 each",
        op.name()
    );
}

#[test]
fn scan_kernels_match_per_element_fold_all_ops() {
    let mk: Vec<fn() -> OpRef<i64>> = vec![
        ops::bxor,
        ops::bor,
        ops::sum_i64,
        ops::max_i64,
        ops::min_i64,
        || ops::expensive_bxor(16), // no static scan kernel → dyn fallback
    ];
    forall(cases(10), |g| {
        for n in [0usize, 1, 2, 5, 8] {
            for width in [0usize, 1, 17] {
                let rows: Vec<i64> = (0..n * width).map(|_| g.i64()).collect();
                for f in &mk {
                    assert_scan_equiv(&f(), &rows, width, n);
                }
                let urows: Vec<u64> = (0..n * width).map(|_| g.u64()).collect();
                assert_scan_equiv(&ops::sum_u64(), &urows, width, n);
                let rrows: Vec<Rec2> = (0..n * width).map(|_| rec2_of(g)).collect();
                assert_scan_equiv(&ops::rec2_compose(), &rrows, width, n);
                let srows: Vec<Seg<i64>> =
                    (0..n * width).map(|_| Seg::new(g.bool(), g.i64())).collect();
                assert_scan_equiv(&seg_sum_i64(), &srows, width, n);
            }
        }
    });
}

#[test]
fn scan_kernel_matches_per_element_fold_f64_bitwise() {
    // Float prefix sums are the reassociation hazard: the tight-loop
    // kernel must fold rows in exactly the per-element order, bit for bit.
    forall(cases(10), |g| {
        for n in [2usize, 5, 8] {
            for width in [1usize, 17, 64] {
                let rows: Vec<f64> =
                    (0..n * width).map(|_| g.f32_in(-1e6, 1e6) as f64).collect();
                let op = ops::sum_f64();
                let mut fast = rows.clone();
                op.kernel().scan_sharded(0, &mut fast, width, n);
                let mut pe = rows.clone();
                op.kernel_per_element().scan_sharded(0, &mut pe, width, n);
                let fb: Vec<u64> = fast.iter().map(|x| x.to_bits()).collect();
                let pb: Vec<u64> = pe.iter().map(|x| x.to_bits()).collect();
                assert_eq!(fb, pb, "sum_f64 scan n {n} width {width}: not bit-identical");
            }
        }
    });
}

/// Every exclusive-scan algorithm in the library (which now includes the
/// auto block decomposition and rsag), plus variants that force the
/// multi-chunk, hierarchical and decomposed-group paths at these small m
/// (the auto policy would pick g = 1 here, so the forced groups are what
/// actually exercise the transpose/return phases).
fn algorithms<T: Elem>() -> Vec<Box<dyn ScanAlgorithm<T>>> {
    let mut algos = all_exscan_algorithms::<T>();
    algos.push(Box::new(ExscanChunked::with_chunk_elems(7)));
    algos.push(Box::new(ExscanHierarchical::new(3)));
    algos.push(Box::new(ExscanBlock::with_group(2)));
    algos.push(Box::new(ExscanBlock::with_group(4)));
    // Node shapes that leave ragged last groups at the fuzzed p values,
    // forcing the two-level send/bcast/fold phases (the registry's
    // ppn = 4 instance degenerates to plain 123 whenever p ≤ 4).
    algos.push(Box::new(ExscanTwoLevel::new(3)));
    algos.push(Box::new(ExscanTwoLevel::new(5)));
    algos
}

/// Run one algorithm under both world-level dispatch modes with fresh
/// operators, returning ((result, ops), (result, ops)).
fn run_ab<T: Elem>(
    algo: &dyn ScanAlgorithm<T>,
    mk_op: impl Fn() -> OpRef<T>,
    inputs: &[Vec<T>],
) -> ((RunResult<T>, u64), (RunResult<T>, u64)) {
    let p = inputs.len();
    let slice_cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
    let pe_cfg = WorldConfig::new(Topology::flat(p))
        .with_per_element_ops(true)
        .with_trace(true);
    let op = mk_op();
    let slice = run_scan(&slice_cfg, algo, &op, inputs).unwrap();
    let slice_ops = op.applications();
    let op = mk_op();
    let pe = run_scan(&pe_cfg, algo, &op, inputs).unwrap();
    let pe_ops = op.applications();
    ((slice, slice_ops), (pe, pe_ops))
}

fn assert_ab_identical<T: Elem>(
    algo: &dyn ScanAlgorithm<T>,
    slice: (RunResult<T>, u64),
    pe: (RunResult<T>, u64),
    p: usize,
    m: usize,
) {
    let ((slice, slice_ops), (pe, pe_ops)) = (slice, pe);
    assert_eq!(
        slice.outputs,
        pe.outputs,
        "{} p={p} m={m}: slice and per-element outputs must be bit-identical",
        algo.name()
    );
    let (st, pt) = (slice.trace.unwrap(), pe.trace.unwrap());
    assert_eq!(
        st.traces.iter().map(|t| &t.events).collect::<Vec<_>>(),
        pt.traces.iter().map(|t| &t.events).collect::<Vec<_>>(),
        "{} p={p} m={m}: traces diverged between dispatch paths",
        algo.name()
    );
    // The engine changes per-application cost, never application count:
    // sharded counters must equal the trace total on both paths.
    assert_eq!(slice_ops, st.total_ops(), "{} p={p} m={m}: slice counters", algo.name());
    assert_eq!(pe_ops, pt.total_ops(), "{} p={p} m={m}: per-element counters", algo.name());
    assert_eq!(slice_ops, pe_ops, "{} p={p} m={m}: ⊕ counts diverged", algo.name());
}

#[test]
fn world_ab_slice_vs_per_element_bxor_i64() {
    forall(cases(8), |g| {
        let p = g.usize_in(2, 16).max(2);
        let m = *g.choose(&[0usize, 1, 17, 256]);
        let inputs = exscan::bench::inputs_i64(p, m, g.u64());
        for algo in algorithms::<i64>() {
            let (s, e) = run_ab(algo.as_ref(), ops::bxor, &inputs);
            assert_ab_identical(algo.as_ref(), s, e, p, m);
        }
    });
}

#[test]
fn world_ab_slice_vs_per_element_rec2() {
    // Non-commutative float composition: identical operand association on
    // both paths ⇒ bit-identical outputs, no tolerance needed.
    forall(cases(6), |g| {
        let p = g.usize_in(2, 12).max(2);
        let m = *g.choose(&[1usize, 5, 17]);
        let inputs = exscan::bench::inputs_rec2(p, m, g.u64());
        for algo in algorithms::<Rec2>() {
            let (s, e) = run_ab(algo.as_ref(), ops::rec2_compose, &inputs);
            assert_ab_identical(algo.as_ref(), s, e, p, m);
        }
    });
}

/// The A/B must also hold under adversarial delivery: chaos decisions
/// are pure in (seed, src, dst, tag), so a chaos world on the slice path
/// and a chaos world on the per-element path at the same seed inject the
/// identical schedule — outputs and traces must stay bit-identical
/// between the two dispatch modes across the fuzz-style grid.
#[test]
fn world_ab_holds_under_chaos_grid() {
    use exscan::mpi::ChaosConfig;
    for seed in [1u64, 2, 3] {
        for p in [4usize, 7] {
            for m in [0usize, 1, 17] {
                let inputs = exscan::bench::inputs_i64(p, m, seed ^ ((m as u64) << 8));
                for algo in algorithms::<i64>() {
                    let run = |per_element: bool| {
                        let cfg = WorldConfig::new(Topology::flat(p))
                            .with_trace(true)
                            .with_per_element_ops(per_element)
                            .with_chaos(ChaosConfig::new(seed));
                        let op = ops::bxor();
                        let res = run_scan(&cfg, algo.as_ref(), &op, &inputs).unwrap();
                        (res, op.applications())
                    };
                    let (s, e) = (run(false), run(true));
                    assert_ab_identical(algo.as_ref(), s, e, p, m);
                }
            }
        }
    }
}

/// Theorem-1 closed forms hold on the slice-kernel path: the engine must
/// never change an application *count* (the paper's metric), only the
/// per-application constant.
#[test]
fn theorem1_counts_hold_under_slice_dispatch() {
    for p in [2usize, 5, 9, 16, 36] {
        let inputs = exscan::bench::inputs_i64(p, 3, 0xD15);
        let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
        let algo = Exscan123;
        let op = ops::bxor();
        let res = run_scan(&cfg, &algo, &op, &inputs).unwrap();
        let tr = res.trace.unwrap();
        let a: &dyn ScanAlgorithm<i64> = &algo;
        assert_eq!(tr.total_rounds(), a.predicted_rounds(p), "rounds p={p}");
        assert_eq!(tr.last_rank_ops(), a.predicted_ops(p), "last-rank ⊕ p={p}");
        assert_eq!(op.applications(), tr.total_ops(), "counters vs trace p={p}");
    }
}
