//! End-to-end integration over the PJRT artifact path (Layers 1+2+3).
//! Gated on `artifacts/manifest.tsv` — skipped (with a message) when the
//! artifacts have not been built, so `cargo test` works pre-`make
//! artifacts` too.

use exscan::bench::{inputs_i64, inputs_rec2};
use exscan::coll::validate::{assert_exscan_matches, oracle_exscan};
use exscan::prelude::*;
use exscan::runtime::{pjrt_bxor_i64, pjrt_rec2_compose, PjrtRuntime};

fn handle() -> Option<exscan::runtime::PjrtHandle> {
    let h = PjrtRuntime::try_default();
    if h.is_none() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    h
}

#[test]
fn kernel_reduce_matches_native() {
    let Some(h) = handle() else { return };
    for n in [1usize, 100, 256, 1000, 5000] {
        let a: Vec<i64> = (0..n as i64).map(|i| i * 0x9E37 ^ 0x55).collect();
        let mut kernel = (0..n as i64).map(|i| !i).collect::<Vec<_>>();
        let mut native = kernel.clone();
        h.reduce_i64("bxor_i64", &a, &mut kernel).unwrap();
        ops::bxor().reduce_local_sharded(0, &a, &mut native);
        assert_eq!(kernel, native, "n={n}");
    }
}

#[test]
fn kernel_reduce_sum_and_max() {
    let Some(h) = handle() else { return };
    let a: Vec<i64> = (0..777).map(|i| i - 300).collect();
    let mut s = vec![10i64; 777];
    h.reduce_i64("sum_i64", &a, &mut s).unwrap();
    assert_eq!(s[0], -290);
    assert_eq!(s[400], 110);
    let mut mx = vec![0i64; 777];
    h.reduce_i64("max_i64", &a, &mut mx).unwrap();
    assert_eq!(mx[0], 0);
    assert_eq!(mx[500], 200);
}

#[test]
fn kernel_too_large_is_clean_error() {
    let Some(h) = handle() else { return };
    let n = 200_000; // larger than the biggest artifact (131072)
    let a = vec![1i64; n];
    let mut b = vec![0i64; n];
    let err = h.reduce_i64("bxor_i64", &a, &mut b).unwrap_err();
    assert!(format!("{err}").contains("no reduce artifact"), "{err}");
}

#[test]
fn exscan_with_pjrt_operator_all_algorithms() {
    let Some(h) = handle() else { return };
    let p = 9;
    let m = 300;
    let inputs = inputs_i64(p, m, 21);
    let world = WorldConfig::new(Topology::flat(p));
    for algo in exscan::coll::paper_exscan_algorithms::<i64>() {
        let op = pjrt_bxor_i64(h.clone());
        let res = run_scan(&world, algo.as_ref(), &op, &inputs).unwrap();
        assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
    }
}

#[test]
fn matrec_kernel_exscan_matches_native_oracle() {
    let Some(h) = handle() else { return };
    let p = 7;
    let m = 40;
    let inputs = inputs_rec2(p, m, 5);
    let world = WorldConfig::new(Topology::flat(p));
    let op = pjrt_rec2_compose(h.clone());
    let res = run_scan(&world, &Exscan123, &op, &inputs).unwrap();
    let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
    for r in 1..p {
        let e = oracle[r].as_ref().unwrap();
        for (a, b) in res.outputs[r].iter().zip(e) {
            for i in 0..4 {
                assert!((a.a[i] - b.a[i]).abs() < 1e-2, "r={r}");
            }
            for i in 0..2 {
                assert!((a.b[i] - b.b[i]).abs() < 1e-2, "r={r}");
            }
        }
    }
}

#[test]
fn block_exscan_kernel_matches_sequential() {
    let Some(h) = handle() else { return };
    let k = 32;
    for m in [1usize, 17, 256] {
        let data: Vec<i64> = (0..k * m).map(|i| (i as i64).wrapping_mul(0x2545F49)).collect();
        let out = h.block_exscan_i64("bxor_i64", k, &data).unwrap();
        // Row j = XOR of rows 0..j.
        let mut acc = vec![0i64; m];
        for j in 0..k {
            assert_eq!(&out[j * m..(j + 1) * m], &acc[..], "row {j} m={m}");
            for c in 0..m {
                acc[c] ^= data[j * m + c];
            }
        }
    }
}

#[test]
fn runtime_stats_accumulate() {
    let Some(h) = handle() else { return };
    let before = h.stats().unwrap();
    let a = vec![1i64; 64];
    let mut b = vec![2i64; 64];
    h.reduce_i64("bxor_i64", &a, &mut b).unwrap();
    h.reduce_i64("bxor_i64", &a, &mut b).unwrap();
    let after = h.stats().unwrap();
    assert!(after.launches >= before.launches + 2);
    assert!(after.elements >= before.elements + 128);
}
