//! Cross-layer consistency: the cost model's closed forms, the algorithm
//! implementations' own skip lists, the live virtual clock, and the trace
//! replayer must all tell the same story.

use exscan::bench::inputs_i64;
use exscan::cost::{calibrate, predict_flat, CostModel, CostParams};
use exscan::prelude::*;
use exscan::trace::replay::replay_completion;

/// The skip sequences duplicated in cost::calibrate (to avoid a layering
/// cycle) must exactly match the algorithms' own critical_skips.
#[test]
fn calibrate_skips_match_algorithms() {
    for p in 2usize..=600 {
        assert_eq!(
            calibrate::skips_two_op(p),
            <ExscanTwoOp as ScanAlgorithm<i64>>::critical_skips(&ExscanTwoOp, p),
            "two-op p={p}"
        );
        assert_eq!(
            calibrate::skips_one_doubling(p),
            <ExscanOneDoubling as ScanAlgorithm<i64>>::critical_skips(&ExscanOneDoubling, p),
            "1-doubling p={p}"
        );
        assert_eq!(
            calibrate::skips_123(p),
            <Exscan123 as ScanAlgorithm<i64>>::critical_skips(&Exscan123, p),
            "123 p={p}"
        );
        assert_eq!(
            calibrate::ops_123(p),
            <Exscan123 as ScanAlgorithm<i64>>::predicted_ops(&Exscan123, p),
            "123 ops p={p}"
        );
    }
}

/// Live virtual-clock completion == trace replay at the same byte count,
/// for every paper algorithm on a hierarchical topology.
#[test]
fn replay_matches_live_virtual_clock() {
    let params = CostParams::generic();
    for (nodes, rpn) in [(12usize, 1usize), (6, 4), (4, 8)] {
        let topo = Topology::cluster(nodes, rpn);
        let p = topo.size();
        let m = 16usize;
        let inputs = inputs_i64(p, m, 7);
        for algo in exscan::coll::paper_exscan_algorithms::<i64>() {
            let cfg = WorldConfig::new(topo).virtual_clock(params).with_trace(true);
            let res = run_scan(&cfg, algo.as_ref(), &ops::bxor(), &inputs).unwrap();
            let live = res.completion_us() - params.overhead;
            let trace = res.trace.unwrap();
            let model = CostModel::new(params, rpn);
            let replayed = replay_completion(&trace, &model, m * 8);
            assert!(
                (live - replayed).abs() < 1e-6,
                "{} {nodes}x{rpn}: live {live} vs replay {replayed}",
                algo.name()
            );
        }
    }
}

/// Replay lets one traced run predict any m: spot-check against live runs.
#[test]
fn replay_predicts_other_sizes() {
    let params = CostParams::paper_36x1();
    let topo = Topology::cluster(36, 1);
    let cfg = WorldConfig::new(topo).virtual_clock(params).with_trace(true);
    let trace_run = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs_i64(36, 4, 1)).unwrap();
    let trace = trace_run.trace.unwrap();
    let model = CostModel::new(params, 1);
    for m in [1usize, 100, 10_000] {
        let live = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs_i64(36, m, 2)).unwrap();
        let predicted = replay_completion(&trace, &model, m * 8) + params.overhead;
        let actual = live.completion_us();
        assert!(
            (predicted - actual).abs() / actual < 1e-9,
            "m={m}: predicted {predicted} vs live {actual}"
        );
    }
}

/// The closed-form critical-path prediction must agree with the live
/// virtual clock on a flat topology (where the critical path is exact).
#[test]
fn closed_form_matches_live_flat() {
    let params = CostParams::paper_36x1();
    let p = 36;
    for m in [1usize, 1000, 100_000] {
        let cfg = WorldConfig::new(Topology::cluster(p, 1)).virtual_clock(params);
        let live = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs_i64(p, m, 3)).unwrap();
        let pred = predict_flat(
            &<Exscan123 as ScanAlgorithm<i64>>::critical_skips(&Exscan123, p),
            <Exscan123 as ScanAlgorithm<i64>>::predicted_ops(&Exscan123, p),
            p,
            1,
            m * 8,
            &params,
        );
        // The closed form uses the paper's q−1 ⊕ count; the live
        // dependency chain additionally serializes the round-1 sender's
        // W ⊕ V preparation (the paper's ternary-reduce footnote), so
        // allow exactly one γ·bytes of slack.
        let slack = params.gamma * (m * 8) as f64 + 1e-6;
        let diff = (pred.time_us - live.completion_us()).abs();
        assert!(
            diff <= slack + 0.05 * live.completion_us(),
            "m={m}: closed-form {:.2} vs live {:.2} (slack {slack:.2})",
            pred.time_us,
            live.completion_us()
        );
    }
}

/// Calibration must reproduce the paper's orderings (the shape claims).
#[test]
fn calibrated_model_reproduces_paper_shape() {
    use exscan::bench::{table1_rows, PaperConfig};
    let rows = table1_rows(PaperConfig::C36x1, &[1, 10_000, 100_000]).unwrap();
    for r in &rows {
        assert!(r.otd123 <= r.one_doubling + 1e-9);
        assert!(r.otd123 <= r.native + 1e-9);
    }
    // ≥20% native→123 improvement at m = 10⁴ (paper: 25%).
    let mid = rows.iter().find(|r| r.m == 10_000).unwrap();
    assert!((mid.native - mid.otd123) / mid.native > 0.20);
    // two-⊕ loses at m = 10⁵.
    let big = rows.iter().find(|r| r.m == 100_000).unwrap();
    assert!(big.two_op > big.otd123);
}

/// Both embedded configurations fit with sane parameters.
#[test]
fn calibration_reports_sane() {
    for data in [&exscan::cost::PAPER_TABLE1_36X1, &exscan::cost::PAPER_TABLE1_36X32] {
        let rep = exscan::cost::fit_flat(data, 8);
        assert!(rep.rel_rmse < 0.4, "{}: {}", rep.label, rep.rel_rmse);
        assert!(rep.native_rel_rmse < 0.4, "{}: {}", rep.label, rep.native_rel_rmse);
        assert!(rep.params.gamma > 0.0);
        assert!(rep.params.beta_inter + rep.params.beta_intra > 0.0);
        // Native per-byte cost must exceed portable (that is the paper's
        // point: the library implementation can be improved).
        let port_b = rep.params.beta_inter.max(rep.params.beta_intra);
        let nat_b = rep.native_params.beta_inter.max(rep.native_params.beta_intra);
        assert!(nat_b >= port_b * 0.9, "{}: native β {nat_b} vs {port_b}", rep.label);
    }
}
