//! Wire-fault tier acceptance (EXPERIMENTS.md §Robustness): seeded frame
//! faults injected **below** the chaos boundary on every available wire
//! backend. With recovery enabled the faulted runs must be bit-identical
//! to the clean thread-world oracle — outputs, traces, chaos schedule
//! digests — while the repair machinery demonstrably acts (nonzero
//! retransmission counters). With recovery disabled the same storms must
//! surface as typed, attributed transport faults — never a
//! receiver-thread panic — and the scan engine must hold
//! `submitted == completed + failed` through a fault storm.
//!
//! Backends this host cannot provide are skipped via the same
//! [`TransportBackend::probe`] capability check CI's `exscan transports`
//! step uses.

use std::time::Duration;

use exscan::coll::validate::{
    oracle_exscan, wire_fault_differential, wire_fault_no_recovery,
};
use exscan::mpi::{TransportBackend, WireFaultConfig};
use exscan::prelude::*;
use exscan::svc::ReqOp;

/// The three fixed fault seeds of the acceptance gate.
const SEEDS: [u64; 3] = [0xA11CE, 0xB0B0, 0x5EED_F007];

/// Wire backends this host can run (the thread backend has no wire
/// layer, so there is nothing to fault there).
fn wire_backends() -> Vec<TransportBackend> {
    TransportBackend::available()
        .into_iter()
        .filter(|b| *b != TransportBackend::Thread)
        .collect()
}

/// Recovery ≡ oracle at the three fixed seeds, on every wire backend:
/// outputs, traces and chaos digests bit-identical to the thread world,
/// with the sweep retransmitting at least once (the helper itself fails
/// the sweep if the repair machinery never acted).
#[test]
fn recovery_is_bit_identical_to_thread_oracle_at_fixed_seeds() {
    let wires = wire_backends();
    if wires.is_empty() {
        eprintln!("no wire backends available on this host; skipping");
        return;
    }
    let p_values = [2usize, 4, 6];
    let m_values = [0usize, 1, 17];
    for &seed in &SEEDS {
        for &backend in &wires {
            let out = wire_fault_differential(backend, seed, &p_values, &m_values);
            assert!(
                out.failures.is_empty(),
                "wire-fault differential failed (backend={backend}, seed={seed}): {:?}",
                out.failures
            );
            assert!(out.cases > 0);
            assert!(
                out.retransmits >= 1,
                "backend={backend} seed={seed}: no retransmissions exercised"
            );
            assert!(
                out.injected >= 1,
                "backend={backend} seed={seed}: the plan injected nothing"
            );
        }
    }
}

/// The fault plan is replayable: the same sweep at the same seed yields
/// the same injection totals and the same XOR'd `WireFaultReport`
/// digest — the property that makes any failure reproducible from its
/// seed alone.
#[test]
fn fault_plan_replay_digest_equality() {
    let Some(&backend) = wire_backends().first() else {
        eprintln!("no wire backends available on this host; skipping");
        return;
    };
    let p_values = [2usize, 4];
    let m_values = [1usize, 17];
    let a = wire_fault_differential(backend, SEEDS[0], &p_values, &m_values);
    let b = wire_fault_differential(backend, SEEDS[0], &p_values, &m_values);
    assert!(a.failures.is_empty(), "first sweep failed: {:?}", a.failures);
    assert!(b.failures.is_empty(), "second sweep failed: {:?}", b.failures);
    assert_eq!(a.cases, b.cases);
    assert_eq!(
        a.fault_digest, b.fault_digest,
        "same (backend, seed) must replay the identical injection digest"
    );
    assert_eq!((a.injected, a.retransmits), (b.injected, b.retransmits));
    // A different seed must (for these fixed values) fingerprint
    // differently — the digest is not a constant.
    let c = wire_fault_differential(backend, SEEDS[1], &p_values, &m_values);
    assert!(c.failures.is_empty(), "third sweep failed: {:?}", c.failures);
    assert_ne!(a.fault_digest, c.fault_digest, "digest must depend on the seed");
}

/// Recovery disabled: the same seeds must produce typed, attributed
/// transport faults — error chain naming the fault, populated
/// `World::transport_fault`, dead-rank registry entry — and never a
/// receiver-thread panic or a timed-out hang.
#[test]
fn disabled_recovery_yields_typed_attributed_faults() {
    let wires = wire_backends();
    if wires.is_empty() {
        eprintln!("no wire backends available on this host; skipping");
        return;
    }
    for &seed in &SEEDS {
        for &backend in &wires {
            wire_fault_no_recovery(backend, seed, 4).unwrap_or_else(|e| {
                panic!("no-recovery check failed (backend={backend}, seed={seed}): {e}")
            });
        }
    }
}

/// The scan engine rides out a wire-fault storm (recovery on): every
/// request either verifies bit-exactly against its serial oracle or
/// fails typed, `submitted == completed + failed` holds at quiesce, the
/// inflight-bytes gauge drains to zero, and the engine's wire gauges
/// prove the recovery layer acted.
#[test]
fn engine_holds_invariants_through_a_fault_storm() {
    const P: usize = 4;
    const M: usize = 8;
    const REQUESTS: u64 = 48;
    let wires = wire_backends();
    if wires.is_empty() {
        eprintln!("no wire backends available on this host; skipping");
        return;
    }
    for &backend in &wires {
        let cfg = EngineConfig::new(P)
            .with_transport(backend)
            .with_wire_faults(WireFaultConfig::storm(SEEDS[0]));
        let engine = ScanEngine::<i64>::new(cfg)
            .unwrap_or_else(|e| panic!("engine construction failed on {backend}: {e}"));
        let mut handles = Vec::new();
        let mut expected = Vec::new();
        for i in 0..REQUESTS {
            let inputs = exscan::bench::inputs_i64(P, M, 0xF00D ^ i);
            expected.push(oracle_exscan(&inputs, &ops::bxor()));
            handles.push(
                engine
                    .submit(ScanRequest::full(ReqOp::bxor_i64(), inputs))
                    .unwrap_or_else(|e| panic!("submit {i} failed on {backend}: {e}")),
            );
        }
        engine.flush();
        let mut verified = 0u64;
        let mut failed_typed = 0u64;
        for (i, (h, oracle)) in handles.into_iter().zip(expected).enumerate() {
            match h.wait_timeout(Duration::from_secs(120)) {
                Ok(out) => {
                    for (r, want) in oracle.iter().enumerate() {
                        if let Some(want) = want {
                            assert_eq!(
                                &out.outputs[r], want,
                                "member {r} diverged on {backend} (request {i})"
                            );
                        }
                    }
                    verified += 1;
                }
                // A storm can exhaust a retry budget: that must come back
                // typed (RankFailed via the dead-rank registry, or
                // Collective for a non-attributable wave error) — the
                // engine rebuilds and keeps serving either way.
                Err(SvcError::RankFailed { .. }) | Err(SvcError::Collective(_)) => {
                    failed_typed += 1;
                }
                Err(e) => panic!("request {i} on {backend}: unexpected error {e}"),
            }
        }
        // Give the dispatcher a beat to finish its accounting.
        let shared = engine.metrics_shared();
        drop(engine);
        let ms = shared.snapshot();
        assert_eq!(verified + failed_typed, REQUESTS);
        assert_eq!(
            ms.submitted,
            ms.completed + ms.failed,
            "zero-lost-requests invariant broken on {backend}: {ms:?}"
        );
        assert_eq!(ms.submitted, REQUESTS);
        assert_eq!(
            ms.inflight_bytes, 0,
            "inflight-bytes gauge must drain at quiesce on {backend}"
        );
        assert!(
            ms.wire_retransmits + ms.wire_dropped_dups + ms.wire_reconnects >= 1,
            "storm-faulted engine on {backend} shows no recovery activity: {ms:?}"
        );
    }
}

/// Arming wire faults on the thread backend is inert by construction —
/// there is no wire layer below it — so results verify and every wire
/// counter stays zero. (The CLI refuses `--wire-fault-seed` on the
/// thread backend; the library keeps it a no-op.)
#[test]
fn thread_backend_ignores_wire_fault_config() {
    const P: usize = 4;
    const M: usize = 8;
    let inputs = exscan::bench::inputs_i64(P, M, 0xBEEF);
    let cfg = WorldConfig::new(Topology::flat(P))
        .with_wire_faults(WireFaultConfig::storm(1));
    let world: World<i64> = World::new(cfg);
    let op = ops::bxor();
    let outs = world
        .run(|ctx| {
            let mut out = vec![0i64; M];
            Exscan123.run(ctx, &inputs[ctx.rank()], &mut out, &op)?;
            Ok(out)
        })
        .expect("thread world must be untouched by wire-fault config");
    let oracle = oracle_exscan(&inputs, &op);
    for r in 1..P {
        assert_eq!(Some(&outs[r]), oracle[r].as_ref(), "rank {r}");
    }
    let s = world.wire_stats();
    assert_eq!(
        (s.retransmits, s.reconnects, s.dropped_dups, s.faults),
        (0, 0, 0, 0),
        "thread backend must report all-zero wire stats"
    );
    assert!(world.transport_fault().is_none());
}
