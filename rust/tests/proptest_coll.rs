//! Property tests over the whole collective library (the "proptest on
//! coordinator invariants" suite, using the in-tree quickcheck harness).
//!
//! For random (algorithm, p, m, operator, seed):
//!   * the parallel result equals the sequential oracle (rank 0 ignored
//!     for exclusive scans),
//!   * the trace satisfies the one-ported + matching invariants,
//!   * measured rounds equal the closed form,
//!   * ⊕ counts respect the paper's bounds,
//!   * the virtual clock is deterministic and positive.

use exscan::bench::{inputs_i64, inputs_rec2};
use exscan::coll::validate::{assert_exscan_matches, oracle_exscan};
use exscan::prelude::*;
use exscan::util::quickcheck::{cases, forall};

fn random_world(g: &mut exscan::util::quickcheck::Gen) -> (usize, usize, u64) {
    let p = g.usize_in(2, 48).max(2);
    let m = g.usize_in(0, 64);
    let seed = g.u64();
    (p, m, seed)
}

#[test]
fn all_exscan_algorithms_match_oracle_bxor() {
    forall(cases(60), |g| {
        let (p, m, seed) = random_world(g);
        let algos = exscan::coll::all_exscan_algorithms::<i64>();
        let algo = g.choose(&algos);
        let inputs = inputs_i64(p, m, seed);
        let cfg = WorldConfig::new(Topology::flat(p));
        let res = run_scan(&cfg, algo.as_ref(), &ops::bxor(), &inputs).unwrap();
        assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
    });
}

#[test]
fn all_exscan_algorithms_match_oracle_sum() {
    forall(cases(40), |g| {
        let (p, m, seed) = random_world(g);
        let algos = exscan::coll::all_exscan_algorithms::<i64>();
        let algo = g.choose(&algos);
        let inputs = inputs_i64(p, m, seed);
        let cfg = WorldConfig::new(Topology::flat(p));
        let res = run_scan(&cfg, algo.as_ref(), &ops::sum_i64(), &inputs).unwrap();
        assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
    });
}

#[test]
fn noncommutative_operator_order_preserved_everywhere() {
    forall(cases(30), |g| {
        let p = g.usize_in(2, 33).max(2);
        let m = g.usize_in(1, 8).max(1);
        let seed = g.u64();
        let algos = exscan::coll::all_exscan_algorithms::<Rec2>();
        let algo = g.choose(&algos);
        let inputs = inputs_rec2(p, m, seed);
        let cfg = WorldConfig::new(Topology::flat(p));
        let res = run_scan(&cfg, algo.as_ref(), &ops::rec2_compose(), &inputs).unwrap();
        let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
        for r in 1..p {
            let expect = oracle[r].as_ref().unwrap();
            for (a, b) in res.outputs[r].iter().zip(expect) {
                for i in 0..4 {
                    assert!(
                        (a.a[i] - b.a[i]).abs() < 1e-2,
                        "{} p={p} r={r}: {:?} vs {:?}",
                        algo.name(),
                        a,
                        b
                    );
                }
            }
        }
    });
}

#[test]
fn traced_rounds_equal_closed_forms() {
    forall(cases(40), |g| {
        let p = g.usize_in(2, 70).max(2);
        let algos = exscan::coll::paper_exscan_algorithms::<i64>();
        let algo = g.choose(&algos);
        let inputs = inputs_i64(p, 3, g.u64());
        let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
        let res = run_scan(&cfg, algo.as_ref(), &ops::bxor(), &inputs).unwrap();
        let trace = res.trace.unwrap();
        assert_eq!(
            trace.total_rounds(),
            algo.predicted_rounds(p),
            "{} p={p}",
            algo.name()
        );
        assert!(
            exscan::trace::check_all(&trace).is_empty(),
            "{} p={p} violates invariants",
            algo.name()
        );
    });
}

#[test]
fn op_counts_respect_paper_bounds() {
    forall(cases(40), |g| {
        let p = g.usize_in(2, 80).max(2);
        let inputs = inputs_i64(p, 2, g.u64());
        let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);

        // 123: last rank exactly q-1; no rank exceeds q.
        let res = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs).unwrap();
        let tr = res.trace.unwrap();
        let q = <Exscan123 as ScanAlgorithm<i64>>::predicted_rounds(&Exscan123, p);
        assert_eq!(tr.last_rank_ops(), q.saturating_sub(1), "p={p}");
        assert!(tr.max_ops() <= q, "p={p}");

        // 1-doubling: max == ceil(log2(p-1)) — no send-side preparation.
        let res = run_scan(&cfg, &ExscanOneDoubling, &ops::bxor(), &inputs).unwrap();
        let tr = res.trace.unwrap();
        assert_eq!(
            tr.max_ops(),
            <ExscanOneDoubling as ScanAlgorithm<i64>>::predicted_ops(&ExscanOneDoubling, p),
            "p={p}"
        );

        // two-op: never exceeds the paper's 2⌈log₂p⌉−1 critical-chain
        // count, and pays the extra-⊕ penalty vs the inclusive scan.
        let res = run_scan(&cfg, &ExscanTwoOp, &ops::bxor(), &inputs).unwrap();
        let tr = res.trace.unwrap();
        let bound = <ExscanTwoOp as ScanAlgorithm<i64>>::predicted_ops(&ExscanTwoOp, p);
        assert!(tr.max_ops() <= bound, "p={p}: {} > {bound}", tr.max_ops());
        if p >= 8 {
            assert!(tr.max_ops() > exscan::util::ceil_log2(p) - 1, "penalty p={p}");
        }
    });
}

#[test]
fn virtual_clock_deterministic_and_ordered() {
    forall(cases(25), |g| {
        let p = g.usize_in(2, 40).max(2);
        let m = g.usize_in(1, 32).max(1);
        let seed = g.u64();
        let inputs = inputs_i64(p, m, seed);
        let cfg = WorldConfig::new(Topology::cluster(p, 1)).virtual_clock(CostParams::generic());
        let a = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs).unwrap();
        let b = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs).unwrap();
        assert_eq!(a.times_us, b.times_us, "virtual clock must be deterministic");
        assert!(a.completion_us() > 0.0);
        // Completion is bounded below by rounds * alpha (the model floor).
        let q = <Exscan123 as ScanAlgorithm<i64>>::predicted_rounds(&Exscan123, p) as f64;
        assert!(a.completion_us() >= q * CostParams::generic().alpha_inter - 1e-9);
    });
}

#[test]
fn pipelined_chain_random_blocks() {
    forall(cases(30), |g| {
        let p = g.usize_in(2, 20).max(2);
        let m = g.usize_in(0, 200);
        let b = g.usize_in(1, 32).max(1);
        let inputs = inputs_i64(p, m, g.u64());
        let algo = exscan::coll::PipelinedChain::with_blocks(b);
        let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
        let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
        assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        let tr = res.trace.unwrap();
        assert!(exscan::trace::check_all(&tr).is_empty(), "p={p} m={m} b={b}");
        assert_eq!(tr.total_rounds(), algo.rounds_for(p, m), "p={p} m={m} b={b}");
    });
}

#[test]
fn inclusive_scan_property() {
    forall(cases(30), |g| {
        let p = g.usize_in(1, 50).max(1);
        let m = g.usize_in(1, 32).max(1);
        let inputs = inputs_i64(p, m, g.u64());
        let cfg = WorldConfig::new(Topology::flat(p));
        let res = run_scan(&cfg, &ScanDoubling, &ops::bxor(), &inputs).unwrap();
        let oracle = exscan::coll::oracle_scan(&inputs, &ops::bxor());
        assert_eq!(res.outputs, oracle);
    });
}

#[test]
fn hierarchical_random_node_shapes() {
    forall(cases(25), |g| {
        let k = g.usize_in(1, 8).max(1);
        let nodes = g.usize_in(1, 6).max(1);
        // p not necessarily divisible by k: exercise the short-last-node path.
        let p = (nodes * k).saturating_sub(g.usize_in(0, k - 1)).max(2);
        let m = g.usize_in(1, 16).max(1);
        let algo = exscan::coll::ExscanHierarchical::new(k);
        let inputs = inputs_i64(p, m, g.u64());
        let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
        let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
        assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        let tr = res.trace.unwrap();
        assert!(
            exscan::trace::check_all(&tr).is_empty(),
            "invariants p={p} k={k}"
        );
    });
}

#[test]
fn segmented_scan_random_boundaries() {
    use exscan::coll::{seg_sum_i64, Seg};
    forall(cases(25), |g| {
        let p = g.usize_in(2, 40).max(2);
        let counts: Vec<i64> = (0..p).map(|_| (g.u64() % 100) as i64).collect();
        let starts: Vec<bool> =
            (0..p).map(|r| r == 0 || g.bool() && g.bool()).collect(); // ~25% starts
        let inputs: Vec<Vec<Seg<i64>>> =
            (0..p).map(|r| vec![Seg::new(starts[r], counts[r])]).collect();
        let cfg = WorldConfig::new(Topology::flat(p));
        let res = run_scan(&cfg, &Exscan123, &seg_sum_i64(), &inputs).unwrap();
        for r in 1..p {
            if starts[r] {
                continue; // exclusive prefix at a segment start is ignored
            }
            let seg_start = (0..=r - 1).rev().find(|&s| starts[s]).unwrap_or(0);
            let expect: i64 = counts[seg_start..r].iter().sum();
            assert_eq!(res.outputs[r][0].val, expect, "p={p} r={r}");
        }
    });
}
