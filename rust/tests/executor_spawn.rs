//! Thread-spawn accounting for the persistent executor. Isolated in its
//! own test binary (one test, own process) because it asserts on the
//! process-global spawn counter — any concurrently running world would
//! perturb the count.

use exscan::bench::{inputs_i64, BenchConfig, Harness};
use exscan::coll::{Exscan123, ExscanOneDoubling, ScanAlgorithm};
use exscan::mpi::{ops, rank_threads_spawned, Topology, WorldConfig};

#[test]
fn sweep_spawns_threads_once() {
    const P: usize = 6;
    let before = rank_threads_spawned();
    let harness = Harness::new(
        WorldConfig::new(Topology::flat(P)),
        BenchConfig { warmups: 1, reps: 4, validate: true },
    );
    let algos: Vec<&dyn ScanAlgorithm<i64>> = vec![&Exscan123, &ExscanOneDoubling];
    let out = harness
        .sweep(&algos, &ops::bxor(), &[1, 8, 64], |p, m| inputs_i64(p, m, 77))
        .unwrap();
    assert_eq!(out.len(), 6, "2 algorithms x 3 element counts");
    assert_eq!(
        rank_threads_spawned() - before,
        P,
        "a whole sweep must spawn each rank thread exactly once, \
         not once per (algorithm, m) point"
    );
}
