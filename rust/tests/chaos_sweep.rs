//! The differential chaos sweep (EXPERIMENTS.md §Chaos): every registered
//! exscan algorithm, under a seeded adversarial message schedule
//! (embargoed + diverted deliveries, injected scheduler yields), must be
//! bit-identical to its clean run and to the serial oracle, with the
//! Theorem-1 round/⊕ counts intact — across 3 fixed seeds, a
//! non-commutative operator and a multi-chunk m. Plus: lost messages
//! surface as clean attributed `recv_timeout` errors, chaos schedules
//! replay exactly from their seed, and the zero-allocation pool claim
//! holds under chaos.

use std::time::{Duration, Instant};

use exscan::coll::validate::{chaos_fuzz, chaos_pool_steady_state};
use exscan::coll::Exscan123;
use exscan::coll::ScanAlgorithm;
use exscan::mpi::{run_world, ChaosConfig, Topology, World, WorldConfig};
use exscan::prelude::*;

/// The acceptance sweep: ≥ 3 seeds × all registered algorithms ×
/// {bxor_i64, sum_i64, rec2_compose (non-commutative), seg_bxor_i64 /
/// seg_sum_i64 (lifted segmented over `Seg<i64>`)} × m ∈ {0, 1, 17,
/// 4096 (8 chunks on the 512-element chunked variant)}.
#[test]
fn chaos_differential_sweep_three_seeds() {
    let p_values = [2usize, 3, 4, 5, 8, 9, 13];
    let m_values = [0usize, 1, 17, 4096];
    for seed in [1u64, 0xC0FFEE, 0x5EED] {
        let out = chaos_fuzz(seed, &p_values, &m_values);
        assert!(
            out.failures.is_empty(),
            "seed {seed}: {} failures, first: {}",
            out.failures.len(),
            out.failures[0]
        );
        assert!(out.cases > 0);
        // The sweep must actually have been adversarial.
        assert!(
            out.delayed > 0 && out.diverted > 0,
            "seed {seed} injected nothing: {out:?}"
        );
        assert_eq!(out.dropped, 0, "fuzz profile never drops: {out:?}");
    }
}

/// The Acquire/Release inbox (PR 5's memory-ordering downgrade + adaptive
/// spin budget) must be invisible to the chaos layer: at the exact 3 seeds
/// CI pins (`for seed in 1 2 3`), the fuzz grid still passes every
/// differential check and the `ChaosReport` schedule digest replays
/// bit-identically run over run. Chaos decisions are pure functions of
/// (seed, src, dst, tag)/(seed, rank, tick), so any ordering bug that let
/// a message be matched twice, lost, or matched out of its key would
/// surface here as a failure or a digest drift.
#[test]
fn acqrel_inbox_replays_bit_identical_digests_at_ci_seeds() {
    let p_values = [4usize, 7];
    let m_values = [0usize, 1, 17];
    for seed in [1u64, 2, 3] {
        let a = chaos_fuzz(seed, &p_values, &m_values);
        assert!(
            a.failures.is_empty(),
            "seed {seed}: {} failures under the Acquire/Release inbox, first: {}",
            a.failures.len(),
            a.failures[0]
        );
        let b = chaos_fuzz(seed, &p_values, &m_values);
        assert_eq!(
            a.schedule_digest, b.schedule_digest,
            "seed {seed}: ChaosReport digest must replay bit-identically"
        );
        assert_eq!(
            (a.delayed, a.diverted, a.yields, a.dropped),
            (b.delayed, b.diverted, b.yields, b.dropped),
            "seed {seed}: injection totals must replay"
        );
    }
}

/// Replayability: the same seed injects the identical schedule (equal
/// digests, equal injection counts); a different seed does not.
#[test]
fn chaos_schedule_replays_from_seed_alone() {
    let p_values = [5usize, 8];
    let m_values = [1usize, 17];
    let a = chaos_fuzz(9, &p_values, &m_values);
    let b = chaos_fuzz(9, &p_values, &m_values);
    assert!(a.failures.is_empty(), "{:?}", a.failures);
    assert_eq!(a.schedule_digest, b.schedule_digest, "same seed must replay");
    assert_eq!((a.delayed, a.diverted), (b.delayed, b.diverted));
    let c = chaos_fuzz(10, &p_values, &m_values);
    assert_ne!(
        a.schedule_digest, c.schedule_digest,
        "different seeds must inject different schedules"
    );
}

/// Satellite: an injected permanently-dropped message must surface as a
/// clean per-world `recv_timeout` error naming (rank, round, src) — not a
/// hang, and not a corruption of unrelated rounds.
#[test]
fn dropped_message_surfaces_as_attributed_timeout() {
    // Drop exactly (src 0 → dst 1, round 2); rounds 0, 1 and 3 deliver.
    let chaos = ChaosConfig::new(7)
        .with_delay_prob(0.2)
        .with_divert_prob(0.2)
        .with_drop(0, 1, 2);
    let cfg = WorldConfig::new(Topology::flat(2))
        .with_recv_timeout(Duration::from_millis(300))
        .with_chaos(chaos);
    let t0 = Instant::now();
    let res = run_world::<i64, Vec<i64>, _>(&cfg, |ctx| {
        let mut got = Vec::new();
        if ctx.rank() == 0 {
            for round in 0..4u32 {
                ctx.send(round, 1, &[round as i64 * 10])?;
            }
        } else {
            for round in 0..4u32 {
                let mut buf = [0i64];
                ctx.recv(round, 0, &mut buf)?;
                got.push(buf[0]);
            }
        }
        Ok(got)
    });
    let err = format!("{:#}", res.unwrap_err());
    assert!(err.contains("deadlocked"), "unexpected error: {err}");
    assert!(err.contains("rank 1"), "missing receiver rank in: {err}");
    assert!(err.contains("from=0"), "missing sender in: {err}");
    assert!(err.contains("round=2"), "missing round in: {err}");
    assert!(t0.elapsed() >= Duration::from_millis(250), "must respect the deadline");
    assert!(t0.elapsed() < Duration::from_secs(20), "must fail fast, not hang");
}

/// The rounds before the dropped one must still complete correctly — the
/// drop is surgical, not a transport-wide corruption.
#[test]
fn drop_is_surgical_other_rounds_deliver() {
    let chaos = ChaosConfig::new(3)
        .with_delay_prob(0.0)
        .with_divert_prob(0.0)
        .with_yield_prob(0.0)
        .with_drop(0, 1, 9);
    let cfg = WorldConfig::new(Topology::flat(2)).with_chaos(chaos);
    let out = run_world::<i64, Vec<i64>, _>(&cfg, |ctx| {
        let mut got = Vec::new();
        if ctx.rank() == 0 {
            for round in 0..4u32 {
                ctx.send(round, 1, &[round as i64 + 100])?;
            }
        } else {
            for round in 0..4u32 {
                let mut buf = [0i64];
                ctx.recv(round, 0, &mut buf)?;
                got.push(buf[0]);
            }
        }
        Ok(got)
    })
    .unwrap();
    assert_eq!(out[1], vec![100, 101, 102, 103]);
}

/// Acceptance: zero steady-state pool misses under chaos (embargo,
/// diversion and yields active; pool pressure off).
#[test]
fn pool_steady_state_holds_under_chaos() {
    for seed in [1u64, 2, 3] {
        chaos_pool_steady_state(seed).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

/// Chaos pool pressure: every Nth recycled buffer is dropped, forcing
/// continual allocator traffic — results must stay bit-identical anyway
/// (the algorithms never depend on pool hits).
#[test]
fn forced_pool_misses_do_not_change_results() {
    const P: usize = 8;
    const M: usize = 32;
    let inputs = exscan::bench::inputs_i64(P, M, 11);
    let op = ops::bxor();
    let expect = exscan::coll::oracle_exscan(&inputs, &op);
    let chaos = ChaosConfig::new(5).with_pool_discard_period(3);
    let world: World<i64> =
        World::new(WorldConfig::new(Topology::flat(P)).with_chaos(chaos));
    for _ in 0..10 {
        let outputs = world
            .run(|ctx| {
                let mut output = vec![0i64; M];
                ctx.barrier();
                Exscan123.run(ctx, &inputs[ctx.rank()], &mut output, &op)?;
                Ok(output)
            })
            .unwrap();
        for r in 1..P {
            assert_eq!(Some(&outputs[r]), expect[r].as_ref(), "rank {r}");
        }
    }
    let stats = world.pool_stats();
    assert!(
        stats.chaos_discarded > 0,
        "pool pressure must actually discard: {stats:?}"
    );
    assert!(
        stats.misses > 1,
        "forced discards must surface as misses: {stats:?}"
    );
}

/// The chaos world's report is observable and consistent: counts match
/// what two identically seeded worlds inject on identical jobs.
#[test]
fn world_chaos_report_is_deterministic() {
    let mk = || {
        let world: World<i64> = World::new(
            WorldConfig::new(Topology::flat(6)).with_chaos(ChaosConfig::new(21)),
        );
        let inputs = exscan::bench::inputs_i64(6, 8, 21);
        let op = ops::sum_i64();
        for _ in 0..3 {
            world
                .run(|ctx| {
                    let mut output = vec![0i64; 8];
                    ctx.barrier();
                    Exscan123.run(ctx, &inputs[ctx.rank()], &mut output, &op)?;
                    Ok(output)
                })
                .unwrap();
        }
        world.chaos_report().expect("chaos world must report")
    };
    let (a, b) = (mk(), mk());
    assert_eq!(a.schedule_digest, b.schedule_digest);
    assert_eq!(a.delayed, b.delayed);
    assert_eq!(a.diverted, b.diverted);
    assert_eq!(a.dropped, 0);
    assert!(a.delayed + a.diverted > 0, "must inject on a real scan: {a:?}");
    // The event log names concrete (src, dst, round) decisions.
    assert!(!a.events.is_empty());
    assert_eq!(a.events, b.events);
}

/// Non-chaos worlds report nothing and stay byte-for-byte on the old
/// behavior (the chaos hook is one branch per operation).
#[test]
fn non_chaos_world_reports_none() {
    let world: World<i64> = World::new(WorldConfig::new(Topology::flat(2)));
    assert!(world.chaos_report().is_none());
    let stats = world.pool_stats();
    assert_eq!(stats.chaos_discarded, 0);
}
