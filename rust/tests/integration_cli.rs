//! CLI integration: every subcommand runs end to end through `run_argv`
//! (in-process — no subprocess spawning needed).

fn run(args: &[&str]) -> anyhow::Result<()> {
    let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
    exscan::cli::run_argv(&argv)
}

#[test]
fn help_and_empty() {
    run(&["help"]).unwrap();
    run(&[]).unwrap();
}

#[test]
fn unknown_command_errors() {
    let err = run(&["frobnicate"]).unwrap_err();
    assert!(format!("{err}").contains("unknown command"));
}

#[test]
fn predict_runs() {
    run(&["predict", "--p", "36", "--m", "1000"]).unwrap();
    run(&["predict", "--p", "1152", "--m", "1", "--ranks-per-node", "32"]).unwrap();
}

#[test]
fn calibrate_runs() {
    run(&["calibrate"]).unwrap();
}

#[test]
fn trace_all_algorithms() {
    for algo in [
        "123-doubling",
        "1-doubling",
        "two-op-doubling",
        "native-mpich",
        "blelloch",
        "scan-then-shift",
        "linear",
        "pipelined-chain",
        "chunked-doubling",
    ] {
        run(&["trace", "--algo", algo, "--p", "19"]).unwrap();
    }
}

#[test]
fn trace_unknown_algo_errors() {
    assert!(run(&["trace", "--algo", "nope", "--p", "4"]).is_err());
}

#[test]
fn run_small_world() {
    run(&["run", "--algo", "123-doubling", "--p", "8", "--m", "64", "--reps", "3"]).unwrap();
}

#[test]
fn tune_prints_table() {
    run(&["tune", "--p", "4,36,256"]).unwrap();
}

#[test]
fn fuzz_tiny_budget_passes() {
    // Smallest meaningful chaos sweep through the CLI path (the full
    // 3-seed sweep lives in tests/chaos_sweep.rs and the CI fuzz step).
    run(&["fuzz", "--seed", "1", "--quick", "--p-max", "3"]).unwrap();
}

#[test]
fn sweep_quick_writes_csv() {
    let out = std::env::temp_dir().join("exscan_cli_test_figure1.csv");
    let out_s = out.to_str().unwrap();
    run(&["sweep", "--config", "36x1", "--out", out_s, "--quick"]).unwrap();
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(text.starts_with("config,algo,op,p,m,bytes"));
    assert!(text.lines().count() > 10);
    let _ = std::fs::remove_file(&out);
}

#[test]
fn kernel_smoke_if_artifacts() {
    if exscan::runtime::Manifest::default_available() {
        run(&["kernel-smoke"]).unwrap();
    }
}
