//! Transport-level integration for the slot/pool rendezvous path:
//! out-of-order matching under heavy pressure, the zero-allocation
//! steady-state claim, and fast deadlock detection on the slot path
//! (EXPERIMENTS.md §Perf documents the design under test).

use std::time::{Duration, Instant};

use exscan::coll::{Exscan123, ScanAlgorithm};
use exscan::mpi::{run_world, Topology, World, WorldConfig};
use exscan::prelude::*;
use exscan::util::Rng;

/// Thousands of messages matched out of (src, round) order: every rank
/// posts K rounds to every other rank up front (sends never block), then
/// receives them all in a per-rank pseudo-random order. This drives every
/// inbox through slot hits, slot collisions (K × (p−1) ≫ the slot count),
/// the overflow queue and the rank-local pending buffer.
#[test]
fn out_of_order_matching_stress() {
    const P: usize = 8;
    const K: u32 = 60; // P*(P-1)*K = 3360 messages
    let cfg = WorldConfig::new(Topology::flat(P));
    run_world::<i64, (), _>(&cfg, |ctx| {
        let r = ctx.rank();
        // Post everything first: (p-1)*K sends, no receive in between.
        for k in 0..K {
            for dst in 0..P {
                if dst != r {
                    let payload = [((r as i64) << 20) | (k as i64), k as i64];
                    ctx.send(k, dst, &payload)?;
                }
            }
        }
        // Receive in a rank-specific shuffled order over (src, round).
        let mut order: Vec<(usize, u32)> = (0..P)
            .filter(|&s| s != r)
            .flat_map(|s| (0..K).map(move |k| (s, k)))
            .collect();
        let mut rng = Rng::seed_from_u64(0xBADC0DE ^ r as u64);
        for i in (1..order.len()).rev() {
            order.swap(i, rng.gen_range_usize(i + 1));
        }
        for (src, k) in order {
            let mut buf = [0i64; 2];
            ctx.recv(k, src, &mut buf)?;
            assert_eq!(buf[0], ((src as i64) << 20) | (k as i64), "src={src} k={k}");
            assert_eq!(buf[1], k as i64);
        }
        Ok(())
    })
    .unwrap();
}

/// The zero-allocation claim: after warm-up, scan rounds must be served
/// entirely from the recycling pools — the miss counter (each miss is one
/// allocator call) stops moving while the hit counter keeps climbing.
#[test]
fn pool_steady_state_allocates_nothing() {
    const P: usize = 8;
    const M: usize = 64;
    let world: World<i64> = World::new(WorldConfig::new(Topology::flat(P)));
    let inputs: Vec<Vec<i64>> = (0..P).map(|r| vec![r as i64 * 7 + 1; M]).collect();
    let op = ops::bxor();
    let scan_once = || {
        world
            .run(|ctx| {
                let mut output = vec![0i64; M];
                ctx.barrier();
                Exscan123.run(ctx, &inputs[ctx.rank()], &mut output, &op)?;
                Ok(output)
            })
            .unwrap()
    };

    for _ in 0..10 {
        scan_once(); // warm-up: populate every rank's pool to its peak
    }
    let warm = world.pool_stats();
    assert!(warm.recycled > 0, "pools must be circulating: {warm:?}");

    for _ in 0..30 {
        let outputs = scan_once();
        assert_eq!(outputs[P - 1], vec![1 ^ 8 ^ 15 ^ 22 ^ 29 ^ 36 ^ 43; M]);
    }
    let steady = world.pool_stats();
    assert_eq!(
        steady.misses, warm.misses,
        "steady-state scans must perform zero per-message heap allocations \
         (warm: {warm:?}, steady: {steady:?})"
    );
    assert!(steady.hits > warm.hits, "hits must keep accruing: {steady:?}");
    assert!(steady.hit_rate() > 0.5, "overall hit rate too low: {steady:?}");
}

/// The fused-path extension of the zero-allocation claim: with the pooled
/// ctx scratch buffers replacing every algorithm-side `to_vec` temporary
/// (123-doubling's round-1 `W ⊕ V`, two-⊕'s per-round send preparation,
/// mpich's `partial_scan`, …), a full sweep over the paper algorithms plus
/// the chunked pipeline performs zero per-round heap allocations in steady
/// state — asserted via the pool miss counters (every miss is exactly one
/// allocator call, and scratch acquires run through the same pools).
#[test]
fn full_algorithm_sweep_steady_state_allocates_nothing() {
    const P: usize = 8;
    const M: usize = 48;
    let world: World<i64> = World::new(WorldConfig::new(Topology::flat(P)));
    let inputs: Vec<Vec<i64>> =
        (0..P).map(|r| (0..M).map(|i| (r * M + i) as i64).collect()).collect();
    let op = ops::sum_i64();
    let algos: Vec<Box<dyn ScanAlgorithm<i64>>> = {
        let mut a = exscan::coll::paper_exscan_algorithms::<i64>();
        // Multi-chunk schedule (3 chunks at M = 48): scratch + per-chunk
        // messages must all recycle too.
        a.push(Box::new(exscan::coll::ExscanChunked::with_chunk_elems(16)));
        a
    };
    let sweep_once = || {
        let mut last = Vec::new();
        for algo in &algos {
            let outputs = world
                .run(|ctx| {
                    let mut output = vec![0i64; M];
                    ctx.barrier();
                    algo.run(ctx, &inputs[ctx.rank()], &mut output, &op)?;
                    Ok(output)
                })
                .unwrap();
            last = outputs;
        }
        last
    };

    // Warm-up until the pools have met their peak simultaneous demand:
    // keep sweeping until the miss counter stays put across a whole sweep
    // (the demand is bounded by the schedule, so this converges; the
    // bound only guards against a genuine leak).
    let warm = {
        let mut prev = world.pool_stats();
        let mut stable = false;
        for _ in 0..50 {
            sweep_once();
            let now = world.pool_stats();
            if now.misses == prev.misses {
                stable = true;
                prev = now;
                break;
            }
            prev = now;
        }
        assert!(stable, "pool demand must stabilize within 50 warm sweeps: {prev:?}");
        prev
    };
    assert!(warm.recycled > 0, "pools must be circulating: {warm:?}");

    for _ in 0..20 {
        let outputs = sweep_once();
        // Last algorithm's last rank: exclusive sum over ranks 0..P-1.
        for (i, &v) in outputs[P - 1].iter().enumerate() {
            let want: i64 = (0..P - 1).map(|r| (r * M + i) as i64).sum();
            assert_eq!(v, want, "element {i}");
        }
    }
    let steady = world.pool_stats();
    assert_eq!(
        steady.misses, warm.misses,
        "steady-state sweeps must perform zero per-round heap allocations \
         (warm: {warm:?}, steady: {steady:?})"
    );
    assert!(steady.hits > warm.hits, "hits must keep accruing: {steady:?}");
}

/// Deadlock detection on the slot path honours the per-world receive
/// timeout (no process-wide env-var fiddling) and reports who waited for
/// what — promptly.
#[test]
fn deadlock_times_out_fast_on_slot_path() {
    let cfg = WorldConfig::new(Topology::flat(2))
        .with_recv_timeout(Duration::from_millis(300));
    let t0 = Instant::now();
    let res = run_world::<i64, (), _>(&cfg, |ctx| {
        if ctx.rank() == 1 {
            let mut buf = [0i64];
            ctx.recv(5, 0, &mut buf)?; // nobody ever sends this
        }
        Ok(())
    });
    let err = format!("{:#}", res.unwrap_err());
    assert!(err.contains("deadlocked"), "unexpected error: {err}");
    assert!(err.contains("round=5"), "missing round in: {err}");
    assert!(err.contains("from=0"), "missing sender in: {err}");
    assert!(t0.elapsed() >= Duration::from_millis(250), "must respect the deadline");
    assert!(t0.elapsed() < Duration::from_secs(20), "must fail fast");
}

/// A per-world timeout must not poison other worlds: a healthy world
/// constructed alongside keeps the generous default.
#[test]
fn per_world_timeout_is_local() {
    let strict = WorldConfig::new(Topology::flat(2))
        .with_recv_timeout(Duration::from_millis(200));
    assert!(run_world::<i64, (), _>(&strict, |ctx| {
        if ctx.rank() == 0 {
            let mut buf = [0i64];
            ctx.recv(0, 1, &mut buf)?;
        }
        Ok(())
    })
    .is_err());

    // Same process, fresh default world: a slow-but-correct exchange that
    // takes longer than the strict world's 200 ms budget still succeeds.
    let relaxed = WorldConfig::new(Topology::flat(2));
    let out = run_world::<i64, i64, _>(&relaxed, |ctx| {
        let mut buf = [0i64];
        if ctx.rank() == 0 {
            std::thread::sleep(Duration::from_millis(400));
            ctx.send(0, 1, &[77i64])?;
            Ok(0)
        } else {
            ctx.recv(0, 0, &mut buf)?;
            Ok(buf[0])
        }
    })
    .unwrap();
    assert_eq!(out[1], 77);
}

/// End-to-end correctness of every paper algorithm on the new transport —
/// the same numbers as the sequential oracle, across a spread of world
/// sizes that exercises slot collisions and odd topologies.
#[test]
fn all_paper_algorithms_correct_on_slot_transport() {
    use exscan::bench::inputs_i64;
    use exscan::coll::paper_exscan_algorithms;
    use exscan::coll::validate::assert_exscan_matches;
    for p in [2usize, 3, 7, 16, 33] {
        let inputs = inputs_i64(p, 9, 42);
        let cfg = WorldConfig::new(Topology::flat(p));
        for algo in paper_exscan_algorithms::<i64>() {
            let res = run_scan(&cfg, algo.as_ref(), &ops::bxor(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        }
    }
}
