//! Transport- and harness-level integration: larger worlds, hierarchical
//! virtual topologies, the benchmark harness end to end, selection, and
//! failure handling.

use exscan::bench::{inputs_i64, measure_exscan, BenchConfig, Harness};
use exscan::coll::validate::assert_exscan_matches;
use exscan::prelude::*;

#[test]
fn large_thread_world_correct() {
    // 300 real threads through the full algorithm (beyond any p the unit
    // tests touch).
    let p = 300;
    let inputs = inputs_i64(p, 5, 1);
    let cfg = WorldConfig::new(Topology::flat(p));
    let res = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs).unwrap();
    assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
}

#[test]
fn virtual_1152_rank_cluster() {
    // The paper's large configuration end to end, with trace + checks.
    let topo = Topology::cluster(36, 32);
    let p = topo.size();
    let inputs = inputs_i64(p, 4, 2);
    let cfg = WorldConfig::new(topo)
        .virtual_clock(CostParams::paper_36x32())
        .with_trace(true);
    let res = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs).unwrap();
    assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
    assert!(res.completion_us() > 0.0);
    let trace = res.trace.unwrap();
    assert_eq!(trace.total_rounds(), 11); // ⌈log₂(1151) + log₂(4/3)⌉
    assert!(exscan::trace::check_all(&trace).is_empty());
}

#[test]
fn hierarchical_virtual_times_exceed_flat_intra() {
    // Crossing nodes costs more: a 2x8 cluster with expensive inter links
    // must complete slower than a 1x16 single node under the same params.
    let params = CostParams {
        alpha_intra: 0.5,
        alpha_inter: 5.0,
        beta_intra: 1e-5,
        beta_inter: 1e-3,
        gamma: 1e-5,
        overhead: 0.0,
    };
    let inputs = inputs_i64(16, 64, 3);
    let flat = WorldConfig::new(Topology::cluster(1, 16)).virtual_clock(params);
    let split = WorldConfig::new(Topology::cluster(2, 8)).virtual_clock(params);
    let t_flat = run_scan(&flat, &Exscan123, &ops::bxor(), &inputs).unwrap().completion_us();
    let t_split = run_scan(&split, &Exscan123, &ops::bxor(), &inputs).unwrap().completion_us();
    assert!(t_split > t_flat, "split {t_split} must exceed flat {t_flat}");
}

#[test]
fn harness_sweep_returns_grid() {
    let world = WorldConfig::new(Topology::flat(8));
    let h = Harness::new(world, BenchConfig { warmups: 1, reps: 4, validate: true });
    let algos: Vec<Box<dyn ScanAlgorithm<i64>>> = exscan::coll::paper_exscan_algorithms();
    let refs: Vec<&dyn ScanAlgorithm<i64>> = algos.iter().map(|a| a.as_ref()).collect();
    let out = h
        .sweep(&refs, &ops::bxor(), &[1, 16], |p, m| inputs_i64(p, m, 9))
        .unwrap();
    assert_eq!(out.len(), 8); // 4 algos × 2 sizes
    assert!(out.iter().all(|m| m.min_us > 0.0 && m.min_us <= m.mean_us + 1e-9));
}

#[test]
fn measure_validates_outputs() {
    // BenchConfig.validate catches a broken "algorithm": use inclusive
    // scan where an exclusive one is expected → the oracle check panics.
    let world = WorldConfig::new(Topology::flat(4));
    let bench = BenchConfig { warmups: 0, reps: 1, validate: true };
    let inputs = inputs_i64(4, 4, 4);
    let result = std::panic::catch_unwind(|| {
        let _ = measure_exscan(&world, &bench, &ScanDoubling, &ops::bxor(), &inputs);
    });
    assert!(result.is_err(), "validation must reject an inclusive scan");
}

#[test]
fn selection_prefers_123_small_pipeline_large() {
    use exscan::coll::select_exscan;
    let params = CostParams::paper_36x1();
    let small = select_exscan::<i64>(36, 4, &params, 1);
    assert!(small.name().contains("doubling"), "{}", small.name());
    let huge = select_exscan::<i64>(8, 4_000_000, &params, 1);
    assert_eq!(huge.name(), "pipelined-chain");
}

#[test]
fn zero_and_one_rank_worlds() {
    let inputs = inputs_i64(1, 8, 5);
    let cfg = WorldConfig::new(Topology::flat(1));
    for algo in exscan::coll::all_exscan_algorithms::<i64>() {
        let res = run_scan(&cfg, algo.as_ref(), &ops::bxor(), &inputs).unwrap();
        assert_eq!(res.outputs.len(), 1, "{}", algo.name());
    }
}

#[test]
fn mixed_dtype_worlds() {
    // f64 sums across a 10-rank world (tolerance compare).
    let p = 10;
    let inputs: Vec<Vec<f64>> =
        (0..p).map(|r| (0..16).map(|i| (r * 16 + i) as f64 * 0.25).collect()).collect();
    let cfg = WorldConfig::new(Topology::flat(p));
    let res = run_scan(&cfg, &Exscan123, &ops::sum_f64(), &inputs).unwrap();
    for r in 1..p {
        for i in 0..16 {
            let expect: f64 = (0..r).map(|j| (j * 16 + i) as f64 * 0.25).sum();
            assert!((res.outputs[r][i] - expect).abs() < 1e-9, "r={r} i={i}");
        }
    }
}

#[test]
fn tuning_table_covers_grid() {
    use exscan::coll::TuningTable;
    let t = TuningTable::build(vec![8, 64, 512], CostParams::paper_36x1(), 1);
    assert_eq!(t.choice.len(), 3);
    for row in &t.choice {
        assert_eq!(row.len(), t.size_buckets.len());
        for name in row {
            assert!(exscan::coll::exscan_by_name::<i64>(name).is_some(), "{name}");
        }
    }
}
