//! Cross-backend transport equivalence: the thread world is the oracle,
//! and every other available backend (shm rings, TCP/UDS socket meshes)
//! must be observationally identical to it — same outputs, same per-rank
//! trace event logs, same chaos schedule digests under the same seeds
//! (EXPERIMENTS.md §Transport).
//!
//! Backends that this host cannot provide (e.g. unix sockets on a
//! non-unix runner) are skipped via [`TransportBackend::probe`] — the
//! same capability probe CI's `exscan transports` step uses.

use std::time::{Duration, Instant};

use exscan::coll::validate::chaos_fuzz_on;
use exscan::coll::{all_exscan_algorithms, ScanAlgorithm};
use exscan::mpi::{run_world, TransportBackend};
use exscan::prelude::*;

/// Every backend this host can actually run (always includes `thread`).
fn available() -> Vec<TransportBackend> {
    let avail = TransportBackend::available();
    assert!(
        avail.contains(&TransportBackend::Thread),
        "the thread backend must always be available"
    );
    avail
}

/// Wire backends to hold against the thread oracle.
fn wire_backends() -> Vec<TransportBackend> {
    available()
        .into_iter()
        .filter(|b| *b != TransportBackend::Thread)
        .collect()
}

/// Point-to-point smoke on every available backend: out-of-order tag
/// matching, an empty-payload message, and a multi-round exchange.
#[test]
fn send_recv_smoke_on_every_available_backend() {
    const P: usize = 4;
    const K: u32 = 8;
    for backend in available() {
        let cfg = WorldConfig::new(Topology::flat(P)).with_transport(backend);
        run_world::<i64, (), _>(&cfg, |ctx| {
            let r = ctx.rank();
            // Post all rounds to all peers up front, then drain them in
            // reverse round order — exercises slot + pending matching on
            // top of whatever the backend's delivery order is.
            for k in 0..K {
                for dst in 0..P {
                    if dst != r {
                        ctx.send(k, dst, &[((r as i64) << 8) | k as i64])?;
                    }
                }
            }
            for k in (0..K).rev() {
                for src in 0..P {
                    if src != r {
                        let mut buf = [0i64];
                        ctx.recv(k, src, &mut buf)?;
                        assert_eq!(
                            buf[0],
                            ((src as i64) << 8) | k as i64,
                            "backend={backend} src={src} k={k}"
                        );
                    }
                }
            }
            // Zero-length payload round-trips too (m = 0 collectives).
            let empty: [i64; 0] = [];
            let next = (r + 1) % P;
            let prev = (r + P - 1) % P;
            ctx.send(K, next, &empty)?;
            let mut sink: [i64; 0] = [];
            ctx.recv(K, prev, &mut sink)?;
            Ok(())
        })
        .unwrap_or_else(|e| panic!("smoke failed on backend {backend}: {e:#}"));
    }
}

/// The backend oracle, clean path: every registered exscan algorithm at
/// m ∈ {0, 1, 17, 4096} must produce bit-identical outputs AND bit-
/// identical per-rank trace event logs on every wire backend as on the
/// thread world. Trace equality is the strong form: it pins rounds,
/// message/reduce interleaving and byte counts, not just the numerics.
#[test]
fn clean_trace_equality_across_backends() {
    const P: usize = 6;
    let wires = wire_backends();
    if wires.is_empty() {
        eprintln!("no wire backends available on this host; thread-only run");
        return;
    }
    for m in [0usize, 1, 17, 4096] {
        let inputs = exscan::bench::inputs_i64(P, m, 0xB0A7 ^ m as u64);
        for algo in all_exscan_algorithms::<i64>() {
            let cfg = WorldConfig::new(Topology::flat(P)).with_trace(true);
            let reference = run_scan(&cfg, algo.as_ref(), &ops::bxor(), &inputs)
                .unwrap_or_else(|e| panic!("thread run failed: {} m={m}: {e:#}", algo.name()));
            let ref_trace = reference.trace.as_ref().expect("tracing enabled");
            for &backend in &wires {
                let cfg = WorldConfig::new(Topology::flat(P))
                    .with_trace(true)
                    .with_transport(backend);
                let got = run_scan(&cfg, algo.as_ref(), &ops::bxor(), &inputs)
                    .unwrap_or_else(|e| {
                        panic!("{backend} run failed: {} m={m}: {e:#}", algo.name())
                    });
                assert_eq!(
                    got.outputs,
                    reference.outputs,
                    "outputs diverged from thread oracle: algo={} m={m} backend={backend}",
                    algo.name()
                );
                let got_trace = got.trace.as_ref().expect("tracing enabled");
                assert_eq!(got_trace.traces.len(), ref_trace.traces.len());
                for (a, b) in got_trace.traces.iter().zip(&ref_trace.traces) {
                    assert_eq!(
                        a.events,
                        b.events,
                        "rank {} trace diverged from thread oracle: algo={} m={m} \
                         backend={backend}",
                        a.rank,
                        algo.name()
                    );
                }
            }
        }
    }
}

/// The backend oracle, chaos path: `chaos_fuzz` (every registered
/// algorithm × operator grid, differential vs clean + serial oracle +
/// Theorem-1 counts) must pass on every backend at three fixed seeds —
/// and, because chaos decisions are made above the transport boundary,
/// the injected schedule itself (digest and every injection counter)
/// must be bit-identical across backends.
#[test]
fn chaos_fuzz_digest_identical_across_backends() {
    let p_values = [2usize, 5];
    let m_values = [0usize, 1, 17];
    for seed in [1u64, 0xC0FFEE, 0x5EED_5EED] {
        let oracle = chaos_fuzz_on(TransportBackend::Thread, seed, &p_values, &m_values);
        assert!(
            oracle.failures.is_empty(),
            "thread-backend chaos fuzz failed at seed {seed}: {:?}",
            oracle.failures
        );
        for backend in wire_backends() {
            let got = chaos_fuzz_on(backend, seed, &p_values, &m_values);
            assert!(
                got.failures.is_empty(),
                "{backend} chaos fuzz failed at seed {seed}: {:?}",
                got.failures
            );
            assert_eq!(got.cases, oracle.cases, "case count: seed={seed} {backend}");
            assert_eq!(
                (got.delayed, got.diverted, got.yields, got.dropped),
                (oracle.delayed, oracle.diverted, oracle.yields, oracle.dropped),
                "injection counters must be backend-independent: seed={seed} {backend}"
            );
            assert_eq!(
                got.schedule_digest, oracle.schedule_digest,
                "chaos schedule digest must be backend-independent: seed={seed} {backend}"
            );
        }
    }
}

/// Dropped-frame attribution: a receive that can never be satisfied must
/// fail within the configured deadline on EVERY backend, and the error
/// must name the waiting rank, the missing sender, the round, and the
/// backend it happened on — that attribution line is what turns a hung
/// distributed run into a one-glance diagnosis.
#[test]
fn missing_frame_times_out_attributed_on_every_backend() {
    for backend in available() {
        let cfg = WorldConfig::new(Topology::flat(2))
            .with_recv_timeout(Duration::from_millis(300))
            .with_transport(backend);
        let t0 = Instant::now();
        let res = run_world::<i64, (), _>(&cfg, |ctx| {
            if ctx.rank() == 1 {
                let mut buf = [0i64];
                ctx.recv(5, 0, &mut buf)?; // nobody ever sends this
            }
            Ok(())
        });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("deadlocked"), "[{backend}] unexpected error: {err}");
        assert!(err.contains("rank 1"), "[{backend}] missing rank in: {err}");
        assert!(err.contains("round=5"), "[{backend}] missing round in: {err}");
        assert!(err.contains("from=0"), "[{backend}] missing sender in: {err}");
        assert!(
            err.contains(&format!("transport={backend}")),
            "[{backend}] missing backend attribution in: {err}"
        );
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "[{backend}] must fail fast, took {:?}",
            t0.elapsed()
        );
    }
}

/// The service layer is backend-agnostic: a small engine workload
/// verifies against the serial oracle on every available backend.
#[test]
fn scan_engine_serves_on_every_available_backend() {
    use exscan::coll::validate::oracle_exscan;
    use exscan::svc::ReqOp;

    const P: usize = 4;
    const M: usize = 8;
    for backend in available() {
        let cfg = EngineConfig::new(P).with_transport(backend);
        let engine = ScanEngine::<i64>::new(cfg)
            .unwrap_or_else(|e| panic!("engine construction failed on {backend}: {e}"));
        let mut handles = Vec::new();
        let mut expected = Vec::new();
        for i in 0..12u64 {
            let inputs = exscan::bench::inputs_i64(P, M, 0xFADE ^ i);
            expected.push(oracle_exscan(&inputs, &ops::bxor()));
            handles.push(
                engine
                    .submit(ScanRequest::full(ReqOp::bxor_i64(), inputs))
                    .unwrap_or_else(|e| panic!("submit failed on {backend}: {e}")),
            );
        }
        engine.flush();
        for (i, (h, oracle)) in handles.into_iter().zip(expected).enumerate() {
            let out = h
                .wait_timeout(Duration::from_secs(60))
                .unwrap_or_else(|e| panic!("request {i} failed on {backend}: {e}"));
            for (r, want) in oracle.iter().enumerate() {
                if let Some(want) = want {
                    assert_eq!(
                        &out.outputs[r], want,
                        "member {r} diverged on {backend} (request {i})"
                    );
                }
            }
        }
    }
}
