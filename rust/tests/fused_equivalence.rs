//! Property suite for the fused compute path: for every exscan algorithm
//! × operator × vector length, the fused receive-reduce primitives must
//! produce **bit-identical** outputs (and identical round/op traces) to
//! the pre-fusion two-pass flow, reachable via
//! `WorldConfig::with_unfused_compat(true)`. Bit-identity (not tolerance)
//! is the point: both paths must apply the exact same ⊕ calls in the
//! exact same operand order — any fused-path aliasing or operand-order
//! slip shows up here, including for the non-commutative `rec2_compose`.

use exscan::coll::{all_exscan_algorithms, ExscanChunked, ExscanHierarchical};
use exscan::prelude::*;
use exscan::util::quickcheck::{cases, forall};
use exscan::util::Rng;

/// The satellite's m grid: empty, single element, odd small, multi-chunk.
const MS: [usize; 4] = [0, 1, 17, 256];

/// Every exclusive-scan algorithm in the library, plus variants that
/// force the multi-chunk and hierarchical paths at these small m.
fn algorithms<T: Elem>() -> Vec<Box<dyn ScanAlgorithm<T>>> {
    let mut algos = all_exscan_algorithms::<T>();
    algos.push(Box::new(ExscanChunked::with_chunk_elems(7)));
    algos.push(Box::new(ExscanHierarchical::new(3)));
    algos
}

fn run_pair<T: Elem>(
    algo: &dyn ScanAlgorithm<T>,
    op: &OpRef<T>,
    inputs: &[Vec<T>],
) -> (RunResult<T>, RunResult<T>) {
    let p = inputs.len();
    let fused_cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
    let unfused_cfg = WorldConfig::new(Topology::flat(p))
        .with_unfused_compat(true)
        .with_trace(true);
    let fused = run_scan(&fused_cfg, algo, op, inputs).unwrap();
    let unfused = run_scan(&unfused_cfg, algo, op, inputs).unwrap();
    (fused, unfused)
}

fn assert_identical<T: Elem>(
    algo: &dyn ScanAlgorithm<T>,
    fused: RunResult<T>,
    unfused: RunResult<T>,
    p: usize,
    m: usize,
) {
    assert_eq!(
        fused.outputs,
        unfused.outputs,
        "{} p={p} m={m}: fused and unfused outputs must be bit-identical",
        algo.name()
    );
    let (ft, ut) = (fused.trace.unwrap(), unfused.trace.unwrap());
    assert_eq!(
        ft.total_rounds(),
        ut.total_rounds(),
        "{} p={p} m={m}: round counts diverged",
        algo.name()
    );
    assert_eq!(
        ft.ops_per_rank(),
        ut.ops_per_rank(),
        "{} p={p} m={m}: per-rank ⊕ counts diverged",
        algo.name()
    );
}

fn inputs_u64(p: usize, m: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..p).map(|_| (0..m).map(|_| rng.next_u64()).collect()).collect()
}

#[test]
fn fused_matches_unfused_bxor_i64() {
    forall(cases(12), |g| {
        let p = g.usize_in(2, 20).max(2);
        let m = *g.choose(&MS);
        let inputs = exscan::bench::inputs_i64(p, m, g.u64());
        for algo in algorithms::<i64>() {
            let op = ops::bxor();
            let (f, u) = run_pair(algo.as_ref(), &op, &inputs);
            assert_identical(algo.as_ref(), f, u, p, m);
        }
    });
}

#[test]
fn fused_matches_unfused_sum_u64() {
    forall(cases(12), |g| {
        let p = g.usize_in(2, 20).max(2);
        let m = *g.choose(&MS);
        let inputs = inputs_u64(p, m, g.u64());
        for algo in algorithms::<u64>() {
            let op = ops::sum_u64();
            let (f, u) = run_pair(algo.as_ref(), &op, &inputs);
            assert_identical(algo.as_ref(), f, u, p, m);
        }
    });
}

#[test]
fn fused_matches_unfused_rec2_noncommutative() {
    // Bit-identity over f32 affine composition: both paths must run the
    // exact same association, so even float results compare equal.
    forall(cases(8), |g| {
        let p = g.usize_in(2, 14).max(2);
        let m = *g.choose(&MS);
        let inputs = exscan::bench::inputs_rec2(p, m, g.u64());
        for algo in algorithms::<Rec2>() {
            let op = ops::rec2_compose();
            let (f, u) = run_pair(algo.as_ref(), &op, &inputs);
            assert_identical(algo.as_ref(), f, u, p, m);
        }
    });
}

#[test]
fn every_m_in_the_satellite_grid_is_covered_exhaustively() {
    // Deterministic backstop for the randomized cases above: the paper's
    // four algorithms at a fixed p across the full m grid, both operators
    // that exercise the non-commutative swap path.
    let p = 9;
    for &m in &MS {
        let inputs = exscan::bench::inputs_i64(p, m, 0x5EED ^ m as u64);
        for algo in exscan::coll::paper_exscan_algorithms::<i64>() {
            let op = ops::sum_i64();
            let (f, u) = run_pair(algo.as_ref(), &op, &inputs);
            assert_identical(algo.as_ref(), f, u, p, m);
        }
        let rec_inputs = exscan::bench::inputs_rec2(p, m, 0xC0DE ^ m as u64);
        for algo in exscan::coll::paper_exscan_algorithms::<Rec2>() {
            let op = ops::rec2_compose();
            let (f, u) = run_pair(algo.as_ref(), &op, &rec_inputs);
            assert_identical(algo.as_ref(), f, u, p, m);
        }
    }
}
