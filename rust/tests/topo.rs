//! Integration suite for the topology subsystem: matrix determinism,
//! the two-level scheme's differential correctness on topo-clocked
//! worlds, per-context sub-trace equivalence of the leader phase, and
//! the headline virtual-clock win gates (two-level strictly beats flat
//! 123-doubling on every hierarchical preset, and never wins on the
//! uniform null-hypothesis matrix).

use std::sync::Arc;

use exscan::coll::{oracle_exscan, select_exscan_topo};
use exscan::prelude::*;
use exscan::trace::check_all;

/// Same (shape, seed) must yield a bit-identical matrix no matter how
/// the topology is constructed; different seeds must diverge.
#[test]
fn same_seed_same_matrix_across_construction_paths() {
    let a = Topo::two_level(4, 9, 42);
    let b = Topo::parse("2level:4x9", 42).unwrap();
    assert_eq!(a.matrix_digest(), b.matrix_digest());
    let p = a.size();
    for from in 0..p {
        for to in 0..p {
            assert_eq!(a.alpha(from, to).to_bits(), b.alpha(from, to).to_bits());
            assert_eq!(a.beta(from, to).to_bits(), b.beta(from, to).to_bits());
        }
    }
    assert_ne!(a.matrix_digest(), Topo::two_level(4, 9, 43).matrix_digest());
    assert_ne!(a.matrix_digest(), Topo::flat(36, 42).matrix_digest());
}

/// Two-level under chaos on a topo-clocked world ≡ the sequential
/// oracle, and the virtual completion time is chaos-invariant (the
/// clock advances on message vtimes, which adversarial delivery must
/// not perturb). Three fixed seeds × every hierarchical preset.
#[test]
fn two_level_matches_oracle_under_chaos_on_topo_worlds() {
    for seed in [31u64, 32, 33] {
        for topo in Topo::hierarchical_presets(seed) {
            let p = topo.size();
            let ppn = topo.ranks_per_node();
            let topo = Arc::new(topo);
            let inputs = exscan::bench::inputs_i64(p, 17, seed);
            let algo = ExscanTwoLevel::new(ppn);
            let run = |chaos: bool| {
                let mut cfg = WorldConfig::new(Topology::flat(p))
                    .virtual_clock_topo(topo.clone())
                    .with_trace(true);
                if chaos {
                    cfg = cfg.with_chaos(ChaosConfig::new(seed));
                }
                run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap()
            };
            let (chaos, clean) = (run(true), run(false));
            assert_eq!(chaos.outputs, clean.outputs, "seed {seed} {}", topo.name());
            assert_eq!(
                chaos.completion_us(),
                clean.completion_us(),
                "seed {seed} {}: virtual clock must be chaos-invariant",
                topo.name()
            );
            let oracle = oracle_exscan(&inputs, &ops::bxor());
            for r in 1..p {
                assert_eq!(
                    Some(&chaos.outputs[r]),
                    oracle[r].as_ref(),
                    "seed {seed} {} rank {r}",
                    topo.name()
                );
            }
            let tr = chaos.trace.unwrap();
            assert!(check_all(&tr).is_empty(), "seed {seed} {}", topo.name());
        }
    }
}

/// The leader phase is a genuine 123-doubling: projecting the two-level
/// trace onto the leader context must reproduce, event for event, a
/// standalone `Exscan123` run over the node totals.
#[test]
fn leader_subtrace_matches_standalone_exscan123() {
    const PPN: usize = 3;
    const G: usize = 4;
    const P: usize = G * PPN;
    const M: usize = 5;
    let inputs = exscan::bench::inputs_i64(P, M, 0x70D0);
    let cfg = WorldConfig::new(Topology::flat(P)).with_trace(true);
    let res = run_scan(&cfg, &ExscanTwoLevel::new(PPN), &ops::bxor(), &inputs).unwrap();
    let report = res.trace.unwrap();

    // Node totals: T_j = ⊕ of group j's inputs (elementwise xor here).
    let totals: Vec<Vec<i64>> = (0..G)
        .map(|j| {
            let mut acc = inputs[j * PPN].clone();
            for v in &inputs[j * PPN + 1..(j + 1) * PPN] {
                for (a, b) in acc.iter_mut().zip(v) {
                    *a ^= *b;
                }
            }
            acc
        })
        .collect();
    let leader_cfg = WorldConfig::new(Topology::flat(G)).with_trace(true);
    let standalone = run_scan(&leader_cfg, &Exscan123, &ops::bxor(), &totals).unwrap();
    let serial = standalone.trace.unwrap();

    // Ambient world ctx is 0, so the reserved leader context is 0x8000.
    let leaders: Vec<usize> = (0..G).map(|j| j * PPN).collect();
    let sub = report.for_ctx(0x8000, &leaders);
    for j in 0..G {
        assert_eq!(
            sub.traces[j].events, serial.traces[j].events,
            "leader {j}: sub-trace diverged from standalone 123-doubling"
        );
    }
    assert!(check_all(&sub).is_empty());
    // And the leaders' exscan really computed the group-total prefixes.
    let leader_oracle = oracle_exscan(&totals, &ops::bxor());
    for j in 1..G {
        assert_eq!(Some(&res.outputs[j * PPN]), leader_oracle[j].as_ref(), "leader {j}");
    }
}

/// The headline gates: on every hierarchical preset the two-level scheme
/// strictly beats flat 123-doubling in virtual-clock completion time; on
/// the uniform matrix it never does.
#[test]
fn two_level_beats_flat_123_exactly_on_hierarchical_matrices() {
    const M: usize = 4;
    let seed = 7u64;
    let completion = |topo: &Arc<Topo>, algo: &dyn ScanAlgorithm<i64>| {
        let p = topo.size();
        let cfg = WorldConfig::new(Topology::flat(p)).virtual_clock_topo(topo.clone());
        let inputs = exscan::bench::inputs_i64(p, M, seed);
        run_scan(&cfg, algo, &ops::bxor(), &inputs).unwrap().completion_us()
    };
    for topo in Topo::hierarchical_presets(seed) {
        let ppn = topo.ranks_per_node();
        let topo = Arc::new(topo);
        let two = completion(&topo, &ExscanTwoLevel::new(ppn));
        let flat = completion(&topo, &Exscan123);
        assert!(
            two < flat,
            "{}: two-level {two:.2}µs must strictly beat flat 123 {flat:.2}µs",
            topo.name()
        );
    }
    let uniform = Arc::new(Topo::flat(36, seed));
    let two = completion(&uniform, &ExscanTwoLevel::new(9));
    let flat = completion(&uniform, &Exscan123);
    assert!(
        two >= flat,
        "uniform matrix: two-level {two:.2}µs must not beat flat 123 {flat:.2}µs"
    );
}

/// Topology-aware selection: picks the two-level scheme on hierarchical
/// matrices at round-dominated m, and never even considers it on the
/// uniform matrix (where classic flat selection stays authoritative).
#[test]
fn topo_selection_gates() {
    for topo in Topo::hierarchical_presets(11) {
        for m in [1usize, 16] {
            let a = select_exscan_topo::<i64>(topo.size(), m, &topo);
            assert_eq!(a.name(), "two-level", "{} m={m}", topo.name());
        }
    }
    let uniform = Topo::flat(36, 11);
    for m in [1usize, 64, 4096, 1 << 20] {
        let a = select_exscan_topo::<i64>(36, m, &uniform);
        assert_ne!(a.name(), "two-level", "uniform m={m} picked two-level");
    }
}
