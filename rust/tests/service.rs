//! Acceptance tests for the multi-tenant scan service and the
//! communicator layer under it (ISSUE 4):
//!
//! * ≥ 8 concurrent in-flight exscans on distinct communicators over one
//!   persistent chaos world are bit-identical — outputs AND per-context
//!   traces — to each request run serially on a clean world, at 3 fixed
//!   seeds ([`chaos_concurrent_comms`]).
//! * K coalesced small-m requests pay exactly one collective's rounds
//!   (closed form asserted via the batch's `TraceReport`-measured round
//!   count on each request's [`RequestStats`]).
//! * Segmented coalescing (operator lifting) scatters correct per-request
//!   results; opaque sub-range requests run solo on sub-communicators.
//! * The engine survives an injected lost message: typed
//!   `SvcError::Collective`, world rebuild, subsequent requests succeed.

use std::time::Duration;

use exscan::coll::validate::chaos_concurrent_comms;
use exscan::coll::{oracle_exscan, Exscan123, ScanAlgorithm};
use exscan::mpi::{ops, run_scan, ChaosConfig, TagKey, Topology, WorldConfig};
use exscan::svc::{BatchMode, BatchPolicy, EngineConfig, ReqOp, ScanEngine, ScanRequest, SvcError};
use exscan::util::bits::rounds_123;

const WAIT: Duration = Duration::from_secs(60);

/// A policy with an effectively infinite window: cycles run only on
/// `flush`, making batch composition deterministic for closed-form
/// assertions.
fn manual_policy() -> BatchPolicy {
    BatchPolicy { window: Duration::from_secs(600), ..Default::default() }
}

/// Acceptance: N = 8 concurrent in-flight exscans on distinct
/// communicators over one persistent world, chaos-verified at 3 fixed
/// seeds against serial clean-world execution (outputs and per-context
/// traces bit-identical).
#[test]
fn concurrent_comms_chaos_differential_three_seeds() {
    for seed in [1u64, 0xC0FFEE, 0x5EED] {
        chaos_concurrent_comms(seed, 8).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

/// Acceptance: K batched small-m requests pay one collective's worth of
/// rounds — the closed form `rounds_123(p)` — with per-request amortized
/// rounds `rounds_123(p) / K`, measured from the batch trace.
#[test]
fn batched_requests_pay_one_collectives_rounds() {
    let p = 8;
    let k = 12;
    let m = 4;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    let all_inputs: Vec<Vec<Vec<i64>>> =
        (0..k).map(|i| exscan::bench::inputs_i64(p, m, 100 + i as u64)).collect();
    let handles: Vec<_> = all_inputs
        .iter()
        .map(|inputs| engine.submit_exscan(ReqOp::bxor_i64(), inputs.clone()).unwrap())
        .collect();
    engine.flush();
    for (inputs, h) in all_inputs.iter().zip(handles) {
        let out = h.wait_timeout(WAIT).unwrap();
        // Bit-identical to the request run serially on a clean world.
        let serial =
            run_scan(&WorldConfig::new(Topology::flat(p)), &Exscan123, &ops::bxor(), inputs)
                .unwrap();
        assert_eq!(out.outputs, serial.outputs);
        // Closed-form round accounting.
        assert_eq!(out.stats.mode, BatchMode::Concat);
        assert_eq!(out.stats.batch_size, k);
        assert_eq!(out.stats.coalesced_m, k * m);
        assert_eq!(out.stats.rounds, rounds_123(p), "one collective's rounds for all K");
        let want = rounds_123(p) as f64 / k as f64;
        assert!((out.stats.amortized_rounds - want).abs() < 1e-12);
    }
    let ms = engine.metrics();
    assert_eq!(ms.submitted, k as u64);
    assert_eq!(ms.completed, k as u64);
    assert_eq!(ms.batches, 1, "K same-op full-world requests must coalesce into one");
    assert_eq!(ms.rounds_paid, rounds_123(p) as u64);
    assert_eq!(ms.rounds_solo_equiv, (k as u64) * rounds_123(p) as u64);
    assert!((ms.round_amortization - k as f64).abs() < 1e-9);
}

/// Amortized rounds per request shrink monotonically as the batch grows.
#[test]
fn amortized_rounds_shrink_with_batch_size() {
    let p = 8;
    let m = 2;
    let mut last = f64::INFINITY;
    for k in [1usize, 4, 16] {
        let engine =
            ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy()))
                .unwrap();
        let handles: Vec<_> = (0..k)
            .map(|i| {
                engine
                    .submit_exscan(
                        ReqOp::sum_i64(),
                        exscan::bench::inputs_i64(p, m, i as u64),
                    )
                    .unwrap()
            })
            .collect();
        engine.flush();
        for h in handles {
            h.wait_timeout(WAIT).unwrap();
        }
        let amortized = engine.metrics().amortized_rounds_per_request;
        assert!((amortized - rounds_123(p) as f64 / k as f64).abs() < 1e-9, "k={k}");
        assert!(amortized < last || k == 1, "k={k}: {amortized} !< {last}");
        last = amortized;
    }
}

/// Segmented coalescing: disjoint sub-range requests under a liftable
/// operator pack into lanes of one world-wide lifted scan; each request's
/// scattered result equals its own serial run.
#[test]
fn segmented_coalescing_matches_serial_per_request() {
    let p = 8;
    let m = 3;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    // Ranges: [0,3) and [5,8) share a lane; [1,5) takes a second lane.
    let specs: [(usize, usize); 3] = [(0, 3), (5, 3), (1, 4)];
    let all_inputs: Vec<Vec<Vec<i64>>> = specs
        .iter()
        .enumerate()
        .map(|(i, &(_, span))| exscan::bench::inputs_i64(span, m, 50 + i as u64))
        .collect();
    let handles: Vec<_> = specs
        .iter()
        .zip(&all_inputs)
        .map(|(&(start, _), inputs)| {
            engine
                .submit(ScanRequest::over(ReqOp::sum_i64(), start, inputs.clone()))
                .unwrap()
        })
        .collect();
    engine.flush();
    for ((&(start, span), inputs), h) in specs.iter().zip(&all_inputs).zip(handles) {
        let out = h.wait_timeout(WAIT).unwrap();
        assert_eq!(out.stats.mode, BatchMode::Segmented, "start={start}");
        assert_eq!(out.stats.batch_size, 3);
        assert_eq!(out.stats.coalesced_m, 2 * m, "two lanes of width m");
        // The lifted world-wide scan pays the full-p collective's rounds
        // once for all three requests.
        assert_eq!(out.stats.rounds, rounds_123(p));
        assert_eq!(out.outputs.len(), span);
        let oracle = oracle_exscan(inputs, &ops::sum_i64());
        for cr in 1..span {
            assert_eq!(
                &out.outputs[cr],
                oracle[cr].as_ref().unwrap(),
                "start={start} member {cr}"
            );
        }
        assert_eq!(out.outputs[0], vec![0i64; m], "first member undefined → filler");
    }
    assert_eq!(engine.metrics().segmented_batches, 1);
}

/// A mixed cycle: two concat groups (different ops), one opaque sub-range
/// solo, one liftable singleton solo — four concurrent plans, all
/// verified, amortization still ≥ 1.
#[test]
fn mixed_cycle_runs_all_plans_concurrently() {
    let p = 6;
    let m = 5;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    let bxor_inputs: Vec<Vec<Vec<i64>>> =
        (0..3).map(|i| exscan::bench::inputs_i64(p, m, i as u64)).collect();
    let sum_inputs = exscan::bench::inputs_i64(p, m, 77);
    let solo_opaque = exscan::bench::inputs_i64(3, m, 88); // ranks 1..4
    let solo_lift = exscan::bench::inputs_i64(2, m, 99); // ranks 4..6
    let h_bxor: Vec<_> = bxor_inputs
        .iter()
        .map(|v| engine.submit_exscan(ReqOp::bxor_i64(), v.clone()).unwrap())
        .collect();
    let h_sum = engine.submit_exscan(ReqOp::sum_i64(), sum_inputs.clone()).unwrap();
    let h_opaque = engine
        .submit(ScanRequest::over(ReqOp::from_op(&ops::max_i64()), 1, solo_opaque.clone()))
        .unwrap();
    let h_lift = engine
        .submit(ScanRequest::over(ReqOp::max_i64(), 4, solo_lift.clone()))
        .unwrap();
    engine.flush();

    for (v, h) in bxor_inputs.iter().zip(h_bxor) {
        let out = h.wait_timeout(WAIT).unwrap();
        assert_eq!(out.stats.mode, BatchMode::Concat);
        assert_eq!(out.stats.batch_size, 3);
        let oracle = oracle_exscan(v, &ops::bxor());
        for r in 1..p {
            assert_eq!(&out.outputs[r], oracle[r].as_ref().unwrap());
        }
    }
    let out = h_sum.wait_timeout(WAIT).unwrap();
    assert_eq!(out.stats.mode, BatchMode::Solo, "lone full-world request runs solo");
    let oracle = oracle_exscan(&sum_inputs, &ops::sum_i64());
    for r in 1..p {
        assert_eq!(&out.outputs[r], oracle[r].as_ref().unwrap());
    }
    for (start, inputs, h, op) in [
        (1usize, &solo_opaque, h_opaque, ops::max_i64()),
        (4, &solo_lift, h_lift, ops::max_i64()),
    ] {
        let out = h.wait_timeout(WAIT).unwrap();
        assert_eq!(out.stats.mode, BatchMode::Solo, "start={start}");
        assert_eq!(out.stats.batch_size, 1);
        // Solo sub-range pays the *span's* rounds, not the world's.
        assert_eq!(out.stats.rounds, rounds_123(inputs.len()));
        let oracle = oracle_exscan(inputs, &op);
        for cr in 1..inputs.len() {
            assert_eq!(&out.outputs[cr], oracle[cr].as_ref().unwrap(), "start={start}");
        }
    }
    let ms = engine.metrics();
    assert_eq!(ms.completed, 6);
    assert_eq!(ms.batches, 4);
    assert_eq!(ms.concat_batches, 1);
    assert_eq!(ms.solo_batches, 3);
    assert!(ms.round_amortization >= 1.0, "{ms:?}");
}

/// Service chaos differential at 3 fixed seeds: results under fault
/// injection are bit-identical to each request run serially on a clean
/// world.
#[test]
fn engine_chaos_differential_three_seeds() {
    let p = 8;
    let m = 4;
    for seed in [1u64, 2, 3] {
        let engine = ScanEngine::<i64>::new(
            EngineConfig::new(p)
                .with_policy(manual_policy())
                .with_chaos(ChaosConfig::new(seed)),
        )
        .unwrap();
        // Mixed workload: concat batch + a segmented trio (summed solo
        // cost 2+2+2 beats rounds(8) = 4, so the benefit gate keeps it)
        // + whatever the planner decides for each.
        let full: Vec<Vec<Vec<i64>>> =
            (0..4).map(|i| exscan::bench::inputs_i64(p, m, seed ^ i)).collect();
        let sub_a = exscan::bench::inputs_i64(3, m, seed ^ 10); // ranks 0..3
        let sub_b = exscan::bench::inputs_i64(4, m, seed ^ 11); // ranks 4..8
        let sub_c = exscan::bench::inputs_i64(4, m, seed ^ 12); // ranks 1..5
        let h_full: Vec<_> = full
            .iter()
            .map(|v| engine.submit_exscan(ReqOp::bxor_i64(), v.clone()).unwrap())
            .collect();
        let ha = engine.submit(ScanRequest::over(ReqOp::sum_i64(), 0, sub_a.clone())).unwrap();
        let hb = engine.submit(ScanRequest::over(ReqOp::sum_i64(), 4, sub_b.clone())).unwrap();
        let hc = engine.submit(ScanRequest::over(ReqOp::sum_i64(), 1, sub_c.clone())).unwrap();
        engine.flush();

        let clean = WorldConfig::new(Topology::flat(p));
        for (v, h) in full.iter().zip(h_full) {
            let out = h.wait_timeout(WAIT).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            let serial = run_scan(&clean, &Exscan123, &ops::bxor(), v).unwrap();
            assert_eq!(out.outputs, serial.outputs, "seed {seed}: chaos ≠ serial clean");
        }
        let mut seg_seen = false;
        for (start, inputs, h) in [(0usize, &sub_a, ha), (4, &sub_b, hb), (1, &sub_c, hc)] {
            let out = h.wait_timeout(WAIT).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            seg_seen |= out.stats.mode == BatchMode::Segmented;
            let clean_sub = WorldConfig::new(Topology::flat(inputs.len()));
            let serial = run_scan(&clean_sub, &Exscan123, &ops::sum_i64(), inputs).unwrap();
            assert_eq!(
                out.outputs, serial.outputs,
                "seed {seed} start {start}: chaos ≠ serial clean"
            );
        }
        assert!(seg_seen, "seed {seed}: the trio must coalesce segmented");
        let ms = engine.metrics();
        assert_eq!(ms.failed, 0, "seed {seed}: {ms:?}");
        assert_eq!(ms.completed, 7);
    }
}

/// Nonblocking semantics: `test` reports pending before the flush and
/// complete after; `wait` then returns without blocking.
#[test]
fn handle_test_then_wait() {
    let p = 4;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    let h = engine
        .submit_exscan(ReqOp::sum_i64(), exscan::bench::inputs_i64(p, 2, 5))
        .unwrap();
    assert!(!h.test(), "window still open: must be pending");
    engine.flush();
    let deadline = std::time::Instant::now() + WAIT;
    while !h.test() {
        assert!(std::time::Instant::now() < deadline, "request never completed");
        std::thread::sleep(Duration::from_millis(1));
    }
    let out = h.wait().unwrap();
    assert_eq!(out.stats.batch_size, 1);
}

/// More plans than the context ring: the cycle splits into waves and every
/// request still completes correctly.
#[test]
fn cycle_with_more_plans_than_ring_runs_in_waves() {
    let p = 4;
    let m = 2;
    let k = exscan::svc::CTX_RING + 2; // 34 solo plans → 2 waves
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    // Opaque sub-range requests cannot coalesce: one solo plan each.
    let inputs: Vec<Vec<Vec<i64>>> =
        (0..k).map(|i| exscan::bench::inputs_i64(2, m, i as u64)).collect();
    let handles: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, v)| {
            let start = (i % 3).min(p - 2);
            engine
                .submit(ScanRequest::over(ReqOp::from_op(&ops::bxor()), start, v.clone()))
                .unwrap()
        })
        .collect();
    engine.flush();
    for (v, h) in inputs.iter().zip(handles) {
        let out = h.wait_timeout(WAIT).unwrap();
        let oracle = oracle_exscan(v, &ops::bxor());
        assert_eq!(&out.outputs[1], oracle[1].as_ref().unwrap());
    }
    assert_eq!(engine.metrics().batches, k as u64);
}

/// A lost message inside a batch surfaces as a typed `SvcError::Collective`
/// carrying the attributed deadlock chain; the engine rebuilds its world
/// and keeps serving.
#[test]
fn lost_message_fails_typed_and_engine_recovers() {
    let p = 3;
    // The first ring context is the first id the engine's world allocates
    // (= 1). Drop the round-0 message 0 → 1 on that context: the first
    // full-world plan's collective must time out.
    let doomed_tag = TagKey::new(1, 0, 0).pack();
    let chaos = ChaosConfig::new(5)
        .with_delay_prob(0.0)
        .with_divert_prob(0.0)
        .with_yield_prob(0.0)
        .with_drop(0, 1, doomed_tag);
    let engine = ScanEngine::<i64>::new(
        EngineConfig::new(p)
            .with_policy(manual_policy())
            .with_chaos(chaos)
            .with_recv_timeout(Duration::from_millis(300)),
    )
    .unwrap();

    let h = engine
        .submit_exscan(ReqOp::bxor_i64(), exscan::bench::inputs_i64(p, 2, 1))
        .unwrap();
    engine.flush();
    let err = h.wait_timeout(WAIT).unwrap_err();
    match &err {
        SvcError::Collective(detail) => {
            assert!(detail.contains("deadlocked"), "unattributed failure: {detail}");
        }
        other => panic!("want Collective, got {other:?}"),
    }

    // The engine rebuilt its world and still serves: a sub-range request
    // avoids the doomed (0 → 1, ctx 1, round 0) key entirely.
    let inputs = exscan::bench::inputs_i64(2, 2, 9);
    let h2 = engine
        .submit(ScanRequest::over(ReqOp::bxor_i64(), 1, inputs.clone()))
        .unwrap();
    engine.flush();
    let out = h2.wait_timeout(WAIT).unwrap();
    let oracle = oracle_exscan(&inputs, &ops::bxor());
    assert_eq!(&out.outputs[1], oracle[1].as_ref().unwrap());
    let ms = engine.metrics();
    assert_eq!(ms.failed, 1);
    assert!(ms.worlds_rebuilt >= 1, "{ms:?}");
}

/// Dropping the engine drains queued requests (graceful shutdown), and
/// submissions after shutdown fail typed.
#[test]
fn drop_drains_queued_requests() {
    let p = 4;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    let inputs = exscan::bench::inputs_i64(p, 3, 42);
    let handles: Vec<_> = (0..3)
        .map(|_| engine.submit_exscan(ReqOp::sum_i64(), inputs.clone()).unwrap())
        .collect();
    drop(engine); // no flush: shutdown must cut the window and drain
    let oracle = oracle_exscan(&inputs, &ops::sum_i64());
    for h in handles {
        let out = h.wait_timeout(WAIT).unwrap();
        for r in 1..p {
            assert_eq!(&out.outputs[r], oracle[r].as_ref().unwrap());
        }
    }
}

/// World-level communicator API: dup/split allocate distinct contexts and
/// `predicted_rounds` drives the solo-equivalent accounting.
#[test]
fn world_comm_api_shapes() {
    use exscan::mpi::World;
    let world: World<i64> = World::new(WorldConfig::new(Topology::flat(6)));
    let wc = world.comm_world();
    assert_eq!(wc.ctx(), 0);
    let a = world.dup_comm(&wc);
    let b = world.dup_comm(&wc);
    assert_ne!(a.ctx(), b.ctx());
    let parts = world.split_comm(&wc, &[0, 0, 1, 1, 2, 2]);
    assert_eq!(parts.len(), 3);
    assert_eq!(parts[2].ranks(), &[4, 5]);
    assert!(parts.iter().all(|c| c.ctx() != 0));
    let algo: &dyn ScanAlgorithm<i64> = &Exscan123;
    assert_eq!(algo.predicted_rounds(6), rounds_123(6));
}
