//! Acceptance tests for the multi-tenant scan service and the
//! communicator layer under it (ISSUE 4):
//!
//! * ≥ 8 concurrent in-flight exscans on distinct communicators over one
//!   persistent chaos world are bit-identical — outputs AND per-context
//!   traces — to each request run serially on a clean world, at 3 fixed
//!   seeds ([`chaos_concurrent_comms`]).
//! * K coalesced small-m requests pay exactly one collective's rounds
//!   (closed form asserted via the batch's `TraceReport`-measured round
//!   count on each request's [`RequestStats`]).
//! * Segmented coalescing (operator lifting) scatters correct per-request
//!   results; opaque sub-range requests run solo on sub-communicators.
//! * The engine survives an injected lost message: typed
//!   `SvcError::Collective`, world rebuild, subsequent requests succeed.
//!
//! Failure hardening (ISSUE 6):
//!
//! * Admission control: over-limit submissions fail typed
//!   (`SvcError::Overloaded`) under fail-fast and after the deadline
//!   under blocking mode; rejected requests are never counted submitted.
//! * Rank death under load: a seeded kill fails the wave's handles with
//!   an attributed `SvcError::RankFailed`, the engine rebuilds its world
//!   live (death entry stripped) and keeps serving — zero lost requests.
//! * Drain under chaos: closing the engine mid-chaotic-wave resolves
//!   every outstanding handle and leaves `submitted == completed +
//!   failed` with a fully drained inflight-bytes gauge.
//! * A timed-out (abandoned) handle's late completion is counted in
//!   `MetricsSnapshot::abandoned` instead of vanishing unobserved.

use std::time::Duration;

use exscan::coll::validate::chaos_concurrent_comms;
use exscan::coll::{oracle_exscan, Exscan123, ScanAlgorithm};
use exscan::mpi::{ops, run_scan, ChaosConfig, TagKey, Topology, WorldConfig};
use exscan::svc::{
    AdmissionMode, BatchMode, BatchPolicy, EngineConfig, ReqOp, ScanEngine, ScanRequest,
    ServiceMetrics, SvcError,
};
use exscan::util::bits::rounds_123;

const WAIT: Duration = Duration::from_secs(60);

/// A policy with an effectively infinite window: cycles run only on
/// `flush`, making batch composition deterministic for closed-form
/// assertions.
fn manual_policy() -> BatchPolicy {
    BatchPolicy { window: Duration::from_secs(600), ..Default::default() }
}

/// Acceptance: N = 8 concurrent in-flight exscans on distinct
/// communicators over one persistent world, chaos-verified at 3 fixed
/// seeds against serial clean-world execution (outputs and per-context
/// traces bit-identical).
#[test]
fn concurrent_comms_chaos_differential_three_seeds() {
    for seed in [1u64, 0xC0FFEE, 0x5EED] {
        chaos_concurrent_comms(seed, 8).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

/// Acceptance: K batched small-m requests pay one collective's worth of
/// rounds — the closed form `rounds_123(p)` — with per-request amortized
/// rounds `rounds_123(p) / K`, measured from the batch trace.
#[test]
fn batched_requests_pay_one_collectives_rounds() {
    let p = 8;
    let k = 12;
    let m = 4;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    let all_inputs: Vec<Vec<Vec<i64>>> =
        (0..k).map(|i| exscan::bench::inputs_i64(p, m, 100 + i as u64)).collect();
    let handles: Vec<_> = all_inputs
        .iter()
        .map(|inputs| engine.submit_exscan(ReqOp::bxor_i64(), inputs.clone()).unwrap())
        .collect();
    engine.flush();
    for (inputs, h) in all_inputs.iter().zip(handles) {
        let out = h.wait_timeout(WAIT).unwrap();
        // Bit-identical to the request run serially on a clean world.
        let serial =
            run_scan(&WorldConfig::new(Topology::flat(p)), &Exscan123, &ops::bxor(), inputs)
                .unwrap();
        assert_eq!(out.outputs, serial.outputs);
        // Closed-form round accounting.
        assert_eq!(out.stats.mode, BatchMode::Concat);
        assert_eq!(out.stats.batch_size, k);
        assert_eq!(out.stats.coalesced_m, k * m);
        assert_eq!(out.stats.rounds, rounds_123(p), "one collective's rounds for all K");
        let want = rounds_123(p) as f64 / k as f64;
        assert!((out.stats.amortized_rounds - want).abs() < 1e-12);
    }
    let ms = engine.metrics();
    assert_eq!(ms.submitted, k as u64);
    assert_eq!(ms.completed, k as u64);
    assert_eq!(ms.batches, 1, "K same-op full-world requests must coalesce into one");
    assert_eq!(ms.rounds_paid, rounds_123(p) as u64);
    assert_eq!(ms.rounds_solo_equiv, (k as u64) * rounds_123(p) as u64);
    assert!((ms.round_amortization - k as f64).abs() < 1e-9);
}

/// Amortized rounds per request shrink monotonically as the batch grows.
#[test]
fn amortized_rounds_shrink_with_batch_size() {
    let p = 8;
    let m = 2;
    let mut last = f64::INFINITY;
    for k in [1usize, 4, 16] {
        let engine =
            ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy()))
                .unwrap();
        let handles: Vec<_> = (0..k)
            .map(|i| {
                engine
                    .submit_exscan(
                        ReqOp::sum_i64(),
                        exscan::bench::inputs_i64(p, m, i as u64),
                    )
                    .unwrap()
            })
            .collect();
        engine.flush();
        for h in handles {
            h.wait_timeout(WAIT).unwrap();
        }
        let amortized = engine.metrics().amortized_rounds_per_request;
        assert!((amortized - rounds_123(p) as f64 / k as f64).abs() < 1e-9, "k={k}");
        assert!(amortized < last || k == 1, "k={k}: {amortized} !< {last}");
        last = amortized;
    }
}

/// Segmented coalescing: disjoint sub-range requests under a liftable
/// operator pack into lanes of one world-wide lifted scan; each request's
/// scattered result equals its own serial run.
#[test]
fn segmented_coalescing_matches_serial_per_request() {
    let p = 8;
    let m = 3;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    // Ranges: [0,3) and [5,8) share a lane; [1,5) takes a second lane.
    let specs: [(usize, usize); 3] = [(0, 3), (5, 3), (1, 4)];
    let all_inputs: Vec<Vec<Vec<i64>>> = specs
        .iter()
        .enumerate()
        .map(|(i, &(_, span))| exscan::bench::inputs_i64(span, m, 50 + i as u64))
        .collect();
    let handles: Vec<_> = specs
        .iter()
        .zip(&all_inputs)
        .map(|(&(start, _), inputs)| {
            engine
                .submit(ScanRequest::over(ReqOp::sum_i64(), start, inputs.clone()))
                .unwrap()
        })
        .collect();
    engine.flush();
    for ((&(start, span), inputs), h) in specs.iter().zip(&all_inputs).zip(handles) {
        let out = h.wait_timeout(WAIT).unwrap();
        assert_eq!(out.stats.mode, BatchMode::Segmented, "start={start}");
        assert_eq!(out.stats.batch_size, 3);
        assert_eq!(out.stats.coalesced_m, 2 * m, "two lanes of width m");
        // The lifted world-wide scan pays the full-p collective's rounds
        // once for all three requests.
        assert_eq!(out.stats.rounds, rounds_123(p));
        assert_eq!(out.outputs.len(), span);
        let oracle = oracle_exscan(inputs, &ops::sum_i64());
        for cr in 1..span {
            assert_eq!(
                &out.outputs[cr],
                oracle[cr].as_ref().unwrap(),
                "start={start} member {cr}"
            );
        }
        assert_eq!(out.outputs[0], vec![0i64; m], "first member undefined → filler");
    }
    assert_eq!(engine.metrics().segmented_batches, 1);
}

/// A mixed cycle: two concat groups (different ops), one opaque sub-range
/// solo, one liftable singleton solo — four concurrent plans, all
/// verified, amortization still ≥ 1.
#[test]
fn mixed_cycle_runs_all_plans_concurrently() {
    let p = 6;
    let m = 5;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    let bxor_inputs: Vec<Vec<Vec<i64>>> =
        (0..3).map(|i| exscan::bench::inputs_i64(p, m, i as u64)).collect();
    let sum_inputs = exscan::bench::inputs_i64(p, m, 77);
    let solo_opaque = exscan::bench::inputs_i64(3, m, 88); // ranks 1..4
    let solo_lift = exscan::bench::inputs_i64(2, m, 99); // ranks 4..6
    let h_bxor: Vec<_> = bxor_inputs
        .iter()
        .map(|v| engine.submit_exscan(ReqOp::bxor_i64(), v.clone()).unwrap())
        .collect();
    let h_sum = engine.submit_exscan(ReqOp::sum_i64(), sum_inputs.clone()).unwrap();
    let h_opaque = engine
        .submit(ScanRequest::over(ReqOp::from_op(&ops::max_i64()), 1, solo_opaque.clone()))
        .unwrap();
    let h_lift = engine
        .submit(ScanRequest::over(ReqOp::max_i64(), 4, solo_lift.clone()))
        .unwrap();
    engine.flush();

    for (v, h) in bxor_inputs.iter().zip(h_bxor) {
        let out = h.wait_timeout(WAIT).unwrap();
        assert_eq!(out.stats.mode, BatchMode::Concat);
        assert_eq!(out.stats.batch_size, 3);
        let oracle = oracle_exscan(v, &ops::bxor());
        for r in 1..p {
            assert_eq!(&out.outputs[r], oracle[r].as_ref().unwrap());
        }
    }
    let out = h_sum.wait_timeout(WAIT).unwrap();
    assert_eq!(out.stats.mode, BatchMode::Solo, "lone full-world request runs solo");
    let oracle = oracle_exscan(&sum_inputs, &ops::sum_i64());
    for r in 1..p {
        assert_eq!(&out.outputs[r], oracle[r].as_ref().unwrap());
    }
    for (start, inputs, h, op) in [
        (1usize, &solo_opaque, h_opaque, ops::max_i64()),
        (4, &solo_lift, h_lift, ops::max_i64()),
    ] {
        let out = h.wait_timeout(WAIT).unwrap();
        assert_eq!(out.stats.mode, BatchMode::Solo, "start={start}");
        assert_eq!(out.stats.batch_size, 1);
        // Solo sub-range pays the *span's* rounds, not the world's.
        assert_eq!(out.stats.rounds, rounds_123(inputs.len()));
        let oracle = oracle_exscan(inputs, &op);
        for cr in 1..inputs.len() {
            assert_eq!(&out.outputs[cr], oracle[cr].as_ref().unwrap(), "start={start}");
        }
    }
    let ms = engine.metrics();
    assert_eq!(ms.completed, 6);
    assert_eq!(ms.batches, 4);
    assert_eq!(ms.concat_batches, 1);
    assert_eq!(ms.solo_batches, 3);
    assert!(ms.round_amortization >= 1.0, "{ms:?}");
}

/// Service chaos differential at 3 fixed seeds: results under fault
/// injection are bit-identical to each request run serially on a clean
/// world.
#[test]
fn engine_chaos_differential_three_seeds() {
    let p = 8;
    let m = 4;
    for seed in [1u64, 2, 3] {
        let engine = ScanEngine::<i64>::new(
            EngineConfig::new(p)
                .with_policy(manual_policy())
                .with_chaos(ChaosConfig::new(seed)),
        )
        .unwrap();
        // Mixed workload: concat batch + a segmented trio (summed solo
        // cost 2+2+2 beats rounds(8) = 4, so the benefit gate keeps it)
        // + whatever the planner decides for each.
        let full: Vec<Vec<Vec<i64>>> =
            (0..4).map(|i| exscan::bench::inputs_i64(p, m, seed ^ i)).collect();
        let sub_a = exscan::bench::inputs_i64(3, m, seed ^ 10); // ranks 0..3
        let sub_b = exscan::bench::inputs_i64(4, m, seed ^ 11); // ranks 4..8
        let sub_c = exscan::bench::inputs_i64(4, m, seed ^ 12); // ranks 1..5
        let h_full: Vec<_> = full
            .iter()
            .map(|v| engine.submit_exscan(ReqOp::bxor_i64(), v.clone()).unwrap())
            .collect();
        let ha = engine.submit(ScanRequest::over(ReqOp::sum_i64(), 0, sub_a.clone())).unwrap();
        let hb = engine.submit(ScanRequest::over(ReqOp::sum_i64(), 4, sub_b.clone())).unwrap();
        let hc = engine.submit(ScanRequest::over(ReqOp::sum_i64(), 1, sub_c.clone())).unwrap();
        engine.flush();

        let clean = WorldConfig::new(Topology::flat(p));
        for (v, h) in full.iter().zip(h_full) {
            let out = h.wait_timeout(WAIT).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            let serial = run_scan(&clean, &Exscan123, &ops::bxor(), v).unwrap();
            assert_eq!(out.outputs, serial.outputs, "seed {seed}: chaos ≠ serial clean");
        }
        let mut seg_seen = false;
        for (start, inputs, h) in [(0usize, &sub_a, ha), (4, &sub_b, hb), (1, &sub_c, hc)] {
            let out = h.wait_timeout(WAIT).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            seg_seen |= out.stats.mode == BatchMode::Segmented;
            let clean_sub = WorldConfig::new(Topology::flat(inputs.len()));
            let serial = run_scan(&clean_sub, &Exscan123, &ops::sum_i64(), inputs).unwrap();
            assert_eq!(
                out.outputs, serial.outputs,
                "seed {seed} start {start}: chaos ≠ serial clean"
            );
        }
        assert!(seg_seen, "seed {seed}: the trio must coalesce segmented");
        let ms = engine.metrics();
        assert_eq!(ms.failed, 0, "seed {seed}: {ms:?}");
        assert_eq!(ms.completed, 7);
    }
}

/// Nonblocking semantics: `test` reports pending before the flush and
/// complete after; `wait` then returns without blocking.
#[test]
fn handle_test_then_wait() {
    let p = 4;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    let h = engine
        .submit_exscan(ReqOp::sum_i64(), exscan::bench::inputs_i64(p, 2, 5))
        .unwrap();
    assert!(!h.test(), "window still open: must be pending");
    engine.flush();
    let deadline = std::time::Instant::now() + WAIT;
    while !h.test() {
        assert!(std::time::Instant::now() < deadline, "request never completed");
        std::thread::sleep(Duration::from_millis(1));
    }
    let out = h.wait().unwrap();
    assert_eq!(out.stats.batch_size, 1);
}

/// More plans than the context ring: the cycle splits into waves and every
/// request still completes correctly.
#[test]
fn cycle_with_more_plans_than_ring_runs_in_waves() {
    let p = 4;
    let m = 2;
    let k = exscan::svc::CTX_RING + 2; // 34 solo plans → 2 waves
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    // Opaque sub-range requests cannot coalesce: one solo plan each.
    let inputs: Vec<Vec<Vec<i64>>> =
        (0..k).map(|i| exscan::bench::inputs_i64(2, m, i as u64)).collect();
    let handles: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, v)| {
            let start = (i % 3).min(p - 2);
            engine
                .submit(ScanRequest::over(ReqOp::from_op(&ops::bxor()), start, v.clone()))
                .unwrap()
        })
        .collect();
    engine.flush();
    for (v, h) in inputs.iter().zip(handles) {
        let out = h.wait_timeout(WAIT).unwrap();
        let oracle = oracle_exscan(v, &ops::bxor());
        assert_eq!(&out.outputs[1], oracle[1].as_ref().unwrap());
    }
    assert_eq!(engine.metrics().batches, k as u64);
}

/// A lost message inside a batch surfaces as a typed `SvcError::Collective`
/// carrying the attributed deadlock chain; the engine rebuilds its world
/// and keeps serving.
#[test]
fn lost_message_fails_typed_and_engine_recovers() {
    let p = 3;
    // The first ring context is the first id the engine's world allocates
    // (= 1). Drop the round-0 message 0 → 1 on that context: the first
    // full-world plan's collective must time out.
    let doomed_tag = TagKey::new(1, 0, 0).pack();
    let chaos = ChaosConfig::new(5)
        .with_delay_prob(0.0)
        .with_divert_prob(0.0)
        .with_yield_prob(0.0)
        .with_drop(0, 1, doomed_tag);
    let engine = ScanEngine::<i64>::new(
        EngineConfig::new(p)
            .with_policy(manual_policy())
            .with_chaos(chaos)
            .with_recv_timeout(Duration::from_millis(300)),
    )
    .unwrap();

    let h = engine
        .submit_exscan(ReqOp::bxor_i64(), exscan::bench::inputs_i64(p, 2, 1))
        .unwrap();
    engine.flush();
    let err = h.wait_timeout(WAIT).unwrap_err();
    match &err {
        SvcError::Collective(detail) => {
            assert!(detail.contains("deadlocked"), "unattributed failure: {detail}");
        }
        other => panic!("want Collective, got {other:?}"),
    }

    // The engine rebuilt its world and still serves: a sub-range request
    // avoids the doomed (0 → 1, ctx 1, round 0) key entirely.
    let inputs = exscan::bench::inputs_i64(2, 2, 9);
    let h2 = engine
        .submit(ScanRequest::over(ReqOp::bxor_i64(), 1, inputs.clone()))
        .unwrap();
    engine.flush();
    let out = h2.wait_timeout(WAIT).unwrap();
    let oracle = oracle_exscan(&inputs, &ops::bxor());
    assert_eq!(&out.outputs[1], oracle[1].as_ref().unwrap());
    let ms = engine.metrics();
    assert_eq!(ms.failed, 1);
    assert!(ms.worlds_rebuilt >= 1, "{ms:?}");
}

/// Dropping the engine drains queued requests (graceful shutdown), and
/// submissions after shutdown fail typed.
#[test]
fn drop_drains_queued_requests() {
    let p = 4;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    let inputs = exscan::bench::inputs_i64(p, 3, 42);
    let handles: Vec<_> = (0..3)
        .map(|_| engine.submit_exscan(ReqOp::sum_i64(), inputs.clone()).unwrap())
        .collect();
    drop(engine); // no flush: shutdown must cut the window and drain
    let oracle = oracle_exscan(&inputs, &ops::sum_i64());
    for h in handles {
        let out = h.wait_timeout(WAIT).unwrap();
        for r in 1..p {
            assert_eq!(&out.outputs[r], oracle[r].as_ref().unwrap());
        }
    }
}

/// Poll until the counters quiesce (handle fulfillment races the
/// dispatcher's batch accounting by microseconds) and the given
/// predicate holds, then return the snapshot.
fn await_metrics(
    metrics: &ServiceMetrics,
    what: &str,
    pred: impl Fn(&exscan::svc::MetricsSnapshot) -> bool,
) -> exscan::svc::MetricsSnapshot {
    let deadline = std::time::Instant::now() + WAIT;
    loop {
        let s = metrics.snapshot();
        if s.submitted == s.completed + s.failed && pred(&s) {
            return s;
        }
        assert!(std::time::Instant::now() < deadline, "metrics never quiesced: {what}: {s:?}");
        std::thread::sleep(Duration::from_micros(200));
    }
}

/// Admission control, fail-fast mode: the open-request cap rejects the
/// over-limit submission with a typed `Overloaded`, rejected requests
/// are never counted submitted, and capacity freed by completion admits
/// again.
#[test]
fn backpressure_rejects_typed_overloaded_and_recovers() {
    let p = 4;
    let engine = ScanEngine::<i64>::new(
        EngineConfig::new(p)
            .with_policy(manual_policy())
            .with_admission_limits(4, usize::MAX),
    )
    .unwrap();
    let inputs = exscan::bench::inputs_i64(p, 2, 7);
    // No flush: all four stay open, holding the admission window full.
    let handles: Vec<_> = (0..4)
        .map(|_| engine.submit_exscan(ReqOp::bxor_i64(), inputs.clone()).unwrap())
        .collect();
    let err = engine.submit_exscan(ReqOp::bxor_i64(), inputs.clone()).unwrap_err();
    assert!(matches!(err, SvcError::Overloaded), "want Overloaded, got {err:?}");
    let ms = engine.metrics();
    assert_eq!(ms.submitted, 4, "rejected request must not count as submitted");
    assert_eq!(ms.rejected, 1);

    engine.flush();
    for h in handles {
        h.wait_timeout(WAIT).unwrap();
    }
    // Capacity freed: the same submission is admitted now.
    let m = engine.metrics_shared();
    await_metrics(&m, "after first batch", |s| s.completed == 4);
    let h = engine.submit_exscan(ReqOp::bxor_i64(), inputs).unwrap();
    engine.flush();
    h.wait_timeout(WAIT).unwrap();
    let s = await_metrics(&m, "after recovery", |s| s.completed == 5);
    assert_eq!(s.rejected, 1);
    assert_eq!(s.inflight_bytes, 0, "gauge drained at quiesce");
}

/// Admission control, byte budget: the inflight-bytes cap rejects once
/// payload accumulates, but a request bigger than the whole budget is
/// still admitted when the gauge is at zero (no permanent starvation).
#[test]
fn backpressure_byte_budget_rejects_but_never_starves() {
    let p = 4;
    let m = 4; // payload: 4 ranks × 4 elems × 8 bytes = 128 bytes
    let engine = ScanEngine::<i64>::new(
        EngineConfig::new(p)
            .with_policy(manual_policy())
            .with_admission_limits(4096, 64),
    )
    .unwrap();
    let inputs = exscan::bench::inputs_i64(p, m, 3);
    // 128 bytes > the 64-byte budget, but the gauge is 0 → admitted.
    let h1 = engine.submit_exscan(ReqOp::bxor_i64(), inputs.clone()).unwrap();
    // Now the gauge is nonzero and over budget → rejected.
    let err = engine.submit_exscan(ReqOp::bxor_i64(), inputs.clone()).unwrap_err();
    assert!(matches!(err, SvcError::Overloaded), "want Overloaded, got {err:?}");
    engine.flush();
    h1.wait_timeout(WAIT).unwrap();
    // Drained gauge admits the oversized request again.
    let m_shared = engine.metrics_shared();
    await_metrics(&m_shared, "gauge drain", |s| s.inflight_bytes == 0);
    let h2 = engine.submit_exscan(ReqOp::bxor_i64(), inputs).unwrap();
    engine.flush();
    h2.wait_timeout(WAIT).unwrap();
    assert_eq!(engine.metrics().rejected, 1);
}

/// Admission control, blocking mode: an over-limit submission polls for
/// capacity until the deadline, then rejects typed.
#[test]
fn backpressure_block_mode_times_out_then_rejects() {
    let p = 4;
    let engine = ScanEngine::<i64>::new(
        EngineConfig::new(p)
            .with_policy(manual_policy())
            .with_admission_limits(2, usize::MAX)
            .with_admission_mode(AdmissionMode::Block(Duration::from_millis(150))),
    )
    .unwrap();
    let inputs = exscan::bench::inputs_i64(p, 2, 5);
    let handles: Vec<_> = (0..2)
        .map(|_| engine.submit_exscan(ReqOp::bxor_i64(), inputs.clone()).unwrap())
        .collect();
    let t0 = std::time::Instant::now();
    let err = engine.submit_exscan(ReqOp::bxor_i64(), inputs.clone()).unwrap_err();
    let waited = t0.elapsed();
    assert!(matches!(err, SvcError::Overloaded), "want Overloaded, got {err:?}");
    assert!(waited >= Duration::from_millis(100), "blocked only {waited:?}");
    // With the window draining concurrently, blocking mode admits
    // instead of rejecting.
    engine.flush();
    for h in handles {
        h.wait_timeout(WAIT).unwrap();
    }
    let m = engine.metrics_shared();
    await_metrics(&m, "block-mode drain", |s| s.completed == 2);
    let h = engine.submit_exscan(ReqOp::bxor_i64(), inputs).unwrap();
    engine.flush();
    h.wait_timeout(WAIT).unwrap();
}

/// Rank death under load: the doomed wave's handles all fail with an
/// attributed `RankFailed { rank }`, the engine strips the consumed
/// death entry, rebuilds its world live and keeps serving — with
/// `submitted == completed + failed` intact.
#[test]
fn rank_death_fails_typed_and_engine_rebuilds_live() {
    let p = 4;
    let victim = 2;
    let chaos = ChaosConfig::new(7)
        .with_delay_prob(0.0)
        .with_divert_prob(0.0)
        .with_yield_prob(0.0)
        .with_rank_death(victim, 1); // dies at its first send/receive
    let engine = ScanEngine::<i64>::new(
        EngineConfig::new(p)
            .with_policy(manual_policy())
            .with_chaos(chaos)
            .with_recv_timeout(Duration::from_secs(2)),
    )
    .unwrap();

    // Three full-world requests coalesce into one doomed collective.
    let inputs = exscan::bench::inputs_i64(p, 3, 21);
    let handles: Vec<_> = (0..3)
        .map(|_| engine.submit_exscan(ReqOp::bxor_i64(), inputs.clone()).unwrap())
        .collect();
    engine.flush();
    for h in handles {
        let err = h.wait_timeout(WAIT).unwrap_err();
        match &err {
            SvcError::RankFailed { rank, detail } => {
                assert_eq!(*rank, victim, "attribution names the victim: {detail}");
                assert!(detail.contains("rank-death"), "chain names the fault: {detail}");
            }
            other => panic!("want RankFailed, got {other:?}"),
        }
    }

    // Live rebuild: the same full-world shape (including the victim's
    // rank) succeeds now — the consumed death entry was stripped.
    let h = engine.submit_exscan(ReqOp::bxor_i64(), inputs.clone()).unwrap();
    engine.flush();
    let out = h.wait_timeout(WAIT).unwrap();
    let oracle = oracle_exscan(&inputs, &ops::bxor());
    for r in 1..p {
        assert_eq!(&out.outputs[r], oracle[r].as_ref().unwrap());
    }
    let m = engine.metrics_shared();
    let s = await_metrics(&m, "post-rebuild", |s| s.completed == 1);
    assert_eq!(s.submitted, 4);
    assert_eq!(s.failed, 3);
    assert_eq!(s.rank_failures, 3, "every failure attributed to the kill");
    assert!(s.worlds_rebuilt >= 1);
    assert_eq!(s.inflight_bytes, 0);
}

/// Drain under chaos (ISSUE 6 satellite): close the engine while a
/// chaotic wave is in flight. Every outstanding handle still resolves,
/// nothing is lost (`submitted == completed + failed` after quiesce) and
/// the inflight-bytes gauge returns to zero — no leaked buffers.
#[test]
fn drop_mid_chaotic_wave_resolves_every_handle() {
    let p = 6;
    let engine = ScanEngine::<i64>::new(
        EngineConfig::new(p)
            .with_policy(manual_policy())
            .with_chaos(ChaosConfig::new(0xD1E))
            .with_recv_timeout(Duration::from_secs(10)),
    )
    .unwrap();
    let metrics = engine.metrics_shared();
    let mut handles = Vec::new();
    for i in 0..16u64 {
        let inputs = exscan::bench::inputs_i64(p, 3, 900 + i);
        handles.push(engine.submit_exscan(ReqOp::bxor_i64(), inputs).unwrap());
    }
    for start in [0usize, 3] {
        let inputs = exscan::bench::inputs_i64(3, 3, 950 + start as u64);
        handles.push(engine.submit(ScanRequest::over(ReqOp::sum_i64(), start, inputs)).unwrap());
    }
    engine.flush();
    drop(engine); // close mid-wave: dispatcher must drain, not abandon

    let mut resolved = 0u64;
    for h in handles {
        match h.wait_timeout(WAIT) {
            Ok(_) | Err(SvcError::Collective(_)) | Err(SvcError::Shutdown) => resolved += 1,
            Err(e) => panic!("handle resolved untyped: {e:?}"),
        }
    }
    assert_eq!(resolved, 18, "every outstanding handle resolves");
    let s = metrics.snapshot();
    assert_eq!(s.submitted, 18);
    assert_eq!(s.submitted, s.completed + s.failed, "zero lost requests at shutdown");
    assert_eq!(s.inflight_bytes, 0, "no leaked request buffers");
}

/// A handle abandoned by `wait_timeout` does not lose its request: the
/// dispatcher still resolves it exactly once, and the unobserved late
/// delivery is counted in `MetricsSnapshot::abandoned`.
#[test]
fn timed_out_handle_counts_abandoned_on_late_delivery() {
    let p = 4;
    let engine =
        ScanEngine::<i64>::new(EngineConfig::new(p).with_policy(manual_policy())).unwrap();
    let h = engine
        .submit_exscan(ReqOp::sum_i64(), exscan::bench::inputs_i64(p, 2, 77))
        .unwrap();
    // Window still open (no flush): the wait must time out.
    let err = h.wait_timeout(Duration::from_millis(50)).unwrap_err();
    assert!(matches!(err, SvcError::WaitTimeout), "got {err:?}");
    // The request is still in flight; release it and watch it complete
    // into the abandoned handle.
    engine.flush();
    let m = engine.metrics_shared();
    let s = await_metrics(&m, "abandoned delivery", |s| s.abandoned == 1);
    assert_eq!(s.completed, 1, "request resolved despite the abandoned handle");
    assert_eq!(s.failed, 0);
}

/// World-level communicator API: dup/split allocate distinct contexts and
/// `predicted_rounds` drives the solo-equivalent accounting.
#[test]
fn world_comm_api_shapes() {
    use exscan::mpi::World;
    let world: World<i64> = World::new(WorldConfig::new(Topology::flat(6)));
    let wc = world.comm_world();
    assert_eq!(wc.ctx(), 0);
    let a = world.dup_comm(&wc);
    let b = world.dup_comm(&wc);
    assert_ne!(a.ctx(), b.ctx());
    let parts = world.split_comm(&wc, &[0, 0, 1, 1, 2, 2]);
    assert_eq!(parts.len(), 3);
    assert_eq!(parts[2].ranks(), &[4, 5]);
    assert!(parts.iter().all(|c| c.ctx() != 0));
    let algo: &dyn ScanAlgorithm<i64> = &Exscan123;
    assert_eq!(algo.predicted_rounds(6), rounds_123(6));
}
